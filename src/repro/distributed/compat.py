"""Version-compat shims for JAX API drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``)
across JAX releases.  All shard_map call sites in this repo go through this
wrapper so either JAX generation works unmodified.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:
            # transitional releases expose jax.shard_map with check_rep
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
