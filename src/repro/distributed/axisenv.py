"""Ambient activation-sharding environment.

Model code is mesh-agnostic; the step builder (launch/steps.build_program)
installs this environment around tracing so that models can pin key
activations with logical constraints:

    x = axisenv.constrain(x, "batch", None, "model", None)

Logical names: "batch" -> the (pod, data) axes the batch is split over,
"model"/"kv" -> the tensor-parallel axis (dropped per-tensor when the
dimension is not divisible).  Without an installed environment every
constrain() is a no-op, so single-device smoke tests never see meshes.

Pinning these few points stops GSPMD from propagating bad shardings through
reshapes/gathers (observed: decode attention replicated over the model axis
and the KV cache all-gathered -- 16x flops + GBs of spurious traffic).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_tls = threading.local()


def _env():
    return getattr(_tls, "env", None)


@contextmanager
def activation_axes(*, batch=(), batch_sizes=(), model=None, model_size=1,
                    mesh=None):
    """batch: tuple of mesh axis names; model: mesh axis name or None;
    mesh: the Mesh object (needed by shard_map-based layers)."""
    prev = _env()
    _tls.env = {
        "batch": tuple(batch), "batch_size": int(_prod(batch_sizes)),
        "model": model, "model_size": int(model_size), "mesh": mesh,
    }
    try:
        yield
    finally:
        _tls.env = prev


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def resolve(logical, dim: int):
    env = _env()
    if env is None or logical is None:
        return None
    if logical == "batch":
        if env["batch"] and dim % env["batch_size"] == 0:
            ax = env["batch"]
            return ax if len(ax) > 1 else ax[0]
        return None
    if logical in ("model", "kv", "seq"):
        # "seq": sequence-parallel residual sharding also lands on the
        # model axis (between-block tokens are independent across TP ranks)
        if env["model"] and dim % env["model_size"] == 0:
            return env["model"]
        return None
    raise ValueError(logical)


def constrain(x, *logical):
    """Apply a with_sharding_constraint resolved from logical names.
    No-op when no environment is installed (plain CPU tests)."""
    env = _env()
    if env is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = P(*[resolve(l, d) for l, d in zip(logical, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)
