"""Logical-axis -> mesh-axis sharding rules (DP/TP/EP/FSDP/ZeRO).

Every parameter is annotated once with logical axis names by the model's
``params(mk, cfg)`` function (SpecMaker).  This module resolves those names
to concrete ``PartitionSpec``s for a given mesh + mode:

- ``dp_tp``   : params replicated over (pod, data); tensor-parallel axes
                (vocab/ff/heads/experts/ssm channels) sharded over "model".
- ``fsdp_tp`` : dp_tp + the largest remaining unsharded axis of each big
                param additionally sharded over "data" (ZeRO-3 / FSDP).

Divisibility is checked per-tensor: an axis whose size does not divide the
mesh axis falls back to replication (e.g. granite's single KV head).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axis (dp_tp mode)
TP_RULES = {
    "vocab": "model",
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    # everything else (embed, embed2, head_dim, layer, conv, state, lora,
    # ...) -> replicated
}

# axes eligible for the extra FSDP ("data") shard, in priority order
FSDP_AXES = ("embed", "embed2", "ff", "head_dim", "vocab", "experts")

# parameters smaller than this stay replicated in fsdp mode (norm scales,
# biases -- sharding them only adds collective launches)
FSDP_MIN_SIZE = 1 << 16


def mesh_axis_size(mesh: Mesh, name: Optional[str]) -> int:
    return int(mesh.shape[name]) if name and name in mesh.shape else 1


def spec_for(axes, shape, mesh: Mesh, mode: str = "dp_tp") -> P:
    """Resolve one parameter's logical axes to a PartitionSpec.

    Modes: dp_tp (TP over "model"), fsdp_tp (dp_tp + FSDP over "data"),
    dp_only (no TP -- params replicated, every mesh axis is data parallel;
    the right choice for models far smaller than the pod)."""
    assert len(axes) == len(shape), (axes, shape)
    used = set()
    out = [None] * len(axes)
    # pass 1: tensor-parallel assignment
    if mode != "dp_only":
        for i, (name, dim) in enumerate(zip(axes, shape)):
            m = TP_RULES.get(name)
            if m and m in mesh.shape and m not in used \
                    and dim % mesh.shape[m] == 0:
                out[i] = m
                used.add(m)
    # pass 2: FSDP extra shard over "data"
    if mode == "fsdp_tp" and "data" in mesh.shape and \
            int(np.prod(shape)) >= FSDP_MIN_SIZE:
        for pref in FSDP_AXES:
            done = False
            for i, (name, dim) in enumerate(zip(axes, shape)):
                if name == pref and out[i] is None and \
                        dim % mesh.shape["data"] == 0 and "data" not in used:
                    out[i] = "data"
                    used.add("data")
                    done = True
                    break
            if done:
                break
    return P(*out)


def tree_specs(spec_tree, shape_tree, mesh: Mesh, mode: str = "dp_tp"):
    """Map spec_for over a (logical-axes tree, ShapeDtypeStruct tree)."""
    return jax.tree.map(
        lambda axes, s: spec_for(axes, s.shape, mesh, mode),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def tree_shardings(spec_tree, shape_tree, mesh: Mesh, mode: str = "dp_tp"):
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        tree_specs(spec_tree, shape_tree, mesh, mode))


# ---------------------------------------------------------------------------
# Batch / activation sharding
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh, global_batch: int, mode: str = "dp_tp"):
    """Greedy batch partitioning over (pod, data) -- plus "model" in
    dp_only mode, where the whole pod is data-parallel."""
    names = ("pod", "data", "model") if mode == "dp_only" \
        else ("pod", "data")
    axes = []
    rem = global_batch
    for ax in names:
        if ax in mesh.shape and rem % mesh.shape[ax] == 0 and mesh.shape[ax] > 1:
            axes.append(ax)
            rem //= mesh.shape[ax]
    return tuple(axes)


def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    """PartitionSpec for a (B, ...) array: batch over (pod,data), rest None."""
    ax = batch_axes(mesh, global_batch)
    lead = ax if ax else None
    return P(lead, *([None] * extra_dims))


def cache_spec(axes, shape, mesh: Mesh, global_batch: int) -> P:
    """KV-cache / state sharding: batch dim over (pod,data), model dims per
    TP rules.  `axes` uses logical names with 'batch' marking the batch dim."""
    out = []
    used = set()
    bax = batch_axes(mesh, global_batch)
    for name, dim in zip(axes, shape):
        if name == "batch" and bax and all(a not in used for a in bax):
            out.append(bax if len(bax) > 1 else bax[0])
            used.update(bax)
            continue
        m = TP_RULES.get(name)
        if m and m in mesh.shape and m not in used and dim % mesh.shape[m] == 0:
            out.append(m)
            used.add(m)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state sharding
# ---------------------------------------------------------------------------


def zero_spec(param_spec: P, shape, mesh: Mesh) -> P:
    """Shard optimizer moments over "data" on the first free divisible dim
    (ZeRO-1).  Keeps the param's own spec for the other dims."""
    if "data" not in mesh.shape or int(np.prod(shape)) < FSDP_MIN_SIZE:
        return param_spec
    spec = list(param_spec) + [None] * (len(shape) - len(param_spec))
    if "data" in spec or ("pod", "data") in spec:
        return param_spec
    for i, (cur, dim) in enumerate(zip(spec, shape)):
        if cur is None and dim % mesh.shape["data"] == 0:
            spec[i] = "data"
            return P(*spec)
    return param_spec
