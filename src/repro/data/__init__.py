from repro.data import loader, molecules, tokens  # noqa: F401
