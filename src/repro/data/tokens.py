"""Synthetic LM data: deterministic, step-keyed token streams.

The generator emits structured (not uniform-random) sequences -- a noisy
periodic Markov-ish pattern -- so a model trained for a few hundred steps
shows a clearly decreasing loss (used by examples/train_100m.py).  Batches
are a pure function of (seed, step), which makes data-parallel restart
trivially consistent: after checkpoint restore, step -> batch is identical.
"""
from __future__ import annotations

import numpy as np


def lm_batch(cfg, batch: int, seq: int, *, step: int, seed: int = 0):
    """Returns {"tokens": (B,S) int32, "labels": (B,S) int32} (labels are
    next-token)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    vocab = cfg.vocab_size
    # structured stream: per-row random period + phase, tokens follow
    # t[i] = (base + i * stride) % vocab with occasional noise
    base = rng.integers(0, vocab, size=(batch, 1))
    stride = rng.integers(1, max(2, vocab // 7), size=(batch, 1))
    idx = np.arange(seq + 1)[None, :]
    stream = (base + idx * stride) % vocab
    noise_mask = rng.random((batch, seq + 1)) < 0.05
    noise = rng.integers(0, vocab, size=(batch, seq + 1))
    stream = np.where(noise_mask, noise, stream).astype(np.int32)
    return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}


def frames_batch(cfg, batch: int, seq: int, *, step: int, seed: int = 0):
    """Stub modality frontend: precomputed frame/patch embeddings."""
    rng = np.random.default_rng(np.uint64(seed * 7_000_003 + step))
    return rng.standard_normal((batch, seq, cfg.d_model)).astype(np.float32)


def make_batch(cfg, shape_kind: str, batch: int, seq: int, *, step: int,
               seed: int = 0):
    """Family-aware batch for train/prefill programs."""
    out = lm_batch(cfg, batch, seq, step=step, seed=seed)
    if cfg.family == "vlm":
        out = {"embeds": frames_batch(cfg, batch, seq, step=step, seed=seed),
               "labels": out["labels"]}
        pos = np.broadcast_to(np.arange(seq)[None, None], (3, batch, seq))
        out["positions"] = np.ascontiguousarray(pos).astype(np.int32)
    if cfg.is_encdec:
        out["frames"] = frames_batch(cfg, batch, seq, step=step, seed=seed)
    return out
