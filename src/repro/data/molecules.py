"""Synthetic molecular search space + deterministic QC oracle.

Simulated gate (repro band 4/5): we cannot run NWChem in this container, so
the "quantum chemistry" assay is a deterministic, expensive-ish spectral
computation on the molecular graph -- a fixed-point power iteration on a
graph Hamiltonian whose extreme eigenvalue plays the role of the ionization
potential.  It is (a) deterministic per molecule, (b) smooth in graph
structure (so an MPNN can learn it), and (c) has tunable cost, which is what
the Colmena experiments need (the paper's conclusions are about *steering*,
not about chemistry).

Molecules are random connected graphs ("QM9-like"): <= max_atoms atoms with
one-hot atom types and typed bonds.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class MoleculeSpace:
    num_molecules: int = 10_000
    max_atoms: int = 16
    num_atom_types: int = 8
    num_bond_types: int = 4
    seed: int = 42


def generate_molecule(space: MoleculeSpace, mol_id: int):
    """Deterministic molecule `mol_id` -> (atoms (N,), bonds (N,N), mask (N,))."""
    rng = np.random.default_rng(np.uint64(space.seed * 2_654_435_761 + mol_id))
    N = space.max_atoms
    n = int(rng.integers(6, N + 1))
    atoms = np.zeros(N, np.int32)
    atoms[:n] = rng.integers(0, space.num_atom_types, size=n)
    bonds = np.zeros((N, N), np.int32)
    # random spanning tree keeps the graph connected
    for i in range(1, n):
        j = int(rng.integers(0, i))
        b = int(rng.integers(1, space.num_bond_types))
        bonds[i, j] = bonds[j, i] = b
    # extra edges
    extra = int(rng.integers(0, n))
    for _ in range(extra):
        i, j = rng.integers(0, n, size=2)
        if i != j and bonds[i, j] == 0:
            b = int(rng.integers(1, space.num_bond_types))
            bonds[i, j] = bonds[j, i] = b
    mask = np.zeros(N, np.float32)
    mask[:n] = 1.0
    return atoms, bonds, mask


def featurize(space: MoleculeSpace, mol_ids):
    """Batch featurization -> {"atoms","bonds","mask"} numpy arrays."""
    mols = [generate_molecule(space, int(m)) for m in mol_ids]
    return {
        "atoms": np.stack([m[0] for m in mols]),
        "bonds": np.stack([m[1] for m in mols]),
        "mask": np.stack([m[2] for m in mols]),
    }


def qc_oracle(space: MoleculeSpace, mol_id: int, *, iters: int = 200) -> float:
    """Deterministic 'ionization potential' in [~4, ~12] V.

    Power iteration on H = A_weighted + diag(atom electronegativity); the
    dominant eigenvalue, squashed into a chemically plausible IP range."""
    atoms, bonds, mask = generate_molecule(space, mol_id)
    n = int(mask.sum())
    a = atoms[:n].astype(np.float64)
    W = bonds[:n, :n].astype(np.float64)
    # per-type "electronegativity" pattern
    chi = 1.0 + 0.7 * np.sin(1.0 + a * 1.3) + 0.05 * a
    H = 0.4 * W + np.diag(chi)
    v = np.ones(n) / np.sqrt(n)
    for _ in range(iters):
        v = H @ v
        v = v / max(np.linalg.norm(v), 1e-12)
    lam = float(v @ H @ v)
    # squash to an IP-like range; tail gives rare "high performers" > 10 V
    return 4.0 + 8.0 / (1.0 + np.exp(-(lam - 3.2)))


def oracle_batch(space: MoleculeSpace, mol_ids, **kw):
    return np.array([qc_oracle(space, int(m), **kw) for m in mol_ids],
                    np.float64)
