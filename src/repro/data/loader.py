"""Host-side prefetching data loader.

A background thread produces step-keyed batches (pure functions of the
step counter, see tokens.py) into a bounded queue, overlapping host data
generation with device compute.  On restore, `start_step` realigns the
stream -- the step->batch mapping is deterministic.
"""
from __future__ import annotations

import queue
import threading


class PrefetchLoader:
    def __init__(self, make_batch_fn, *, start_step: int = 0, depth: int = 2):
        self._fn = make_batch_fn
        self._q = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
