"""Message-passing neural network surrogate (the paper's ML assay).

Dense-adjacency MPNN over molecular graphs, mirroring the Gilmer-style MPNN
ensemble Colmena uses to predict ionization potential:

    node features (B, N, F_a one-hot atom types)
    bond features (B, N, N, F_b one-hot bond types; 0 = no bond)

T message-passing steps: messages = edge-MLP(bond) applied to neighbor
states, aggregated by the dense adjacency contraction (the hot spot that
repro.kernels.mpnn_mp implements as a Pallas kernel), followed by a GRU
update.  Readout: masked sum -> MLP -> scalar property.

The *ensemble* dimension is vmapped: params carry a leading (E,) axis and
`ensemble_apply` returns per-member predictions for UCB.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MPNNConfig:
    num_atom_types: int = 8
    num_bond_types: int = 4
    hidden: int = 64
    message_steps: int = 3
    readout_hidden: int = 128
    ensemble: int = 8


def mpnn_params(mk, cfg: MPNNConfig, stacked=()):
    h, fb = cfg.hidden, cfg.num_bond_types
    lead = tuple("layer" for _ in stacked)
    return {
        "embed": mk.param(stacked + (cfg.num_atom_types, h),
                          lead + ("vocab", "embed"), scale=1.0, fan_in=h),
        # edge network: bond features -> (h, h) message matrix
        "edge_w": mk.param(stacked + (fb, h * h), lead + ("embed", "ff"),
                           scale=0.05, fan_in=fb),
        # GRU update
        "gru_wz": mk.param(stacked + (2 * h, h), lead + ("ff", "embed"), fan_in=2 * h),
        "gru_wr": mk.param(stacked + (2 * h, h), lead + ("ff", "embed"), fan_in=2 * h),
        "gru_wh": mk.param(stacked + (2 * h, h), lead + ("ff", "embed"), fan_in=2 * h),
        # readout
        "ro_w1": mk.param(stacked + (h, cfg.readout_hidden),
                          lead + ("embed", "ff"), fan_in=h),
        "ro_b1": mk.param(stacked + (cfg.readout_hidden,), lead + ("ff",),
                          init="zeros"),
        "ro_w2": mk.param(stacked + (cfg.readout_hidden, 1),
                          lead + ("ff", "embed"), fan_in=cfg.readout_hidden),
        "ro_b2": mk.param(stacked + (1,), lead + ("embed",), init="zeros"),
    }


def message_pass_ref(h, edge_mat, adj_mask):
    """One dense message-passing step (the mpnn_mp kernel's contract).

    h (B,N,Hd); edge_mat (B,N,N,Hd,Hd); adj_mask (B,N,N) in {0,1}.
    messages_i = sum_j mask_ij * edge_mat_ij @ h_j
    """
    return jnp.einsum("bijkl,bjl,bij->bik", edge_mat, h, adj_mask)


def mpnn_forward(params, atoms, bonds, mask, cfg: MPNNConfig,
                 impl: str = "ref"):
    """atoms (B,N) int; bonds (B,N,N) int (0=none); mask (B,N) in {0,1}.
    Returns (B,) property prediction."""
    B, N = atoms.shape
    hdim = cfg.hidden
    h = jnp.take(params["embed"], atoms, axis=0)               # (B,N,Hd)
    h = h * mask[..., None]

    bond_oh = jax.nn.one_hot(bonds, cfg.num_bond_types)        # (B,N,N,Fb)
    edge_mat = jnp.einsum("bijf,fk->bijk", bond_oh,
                          params["edge_w"]).reshape(B, N, N, hdim, hdim)
    adj = (bonds > 0).astype(h.dtype) * mask[:, :, None] * mask[:, None, :]

    if impl == "kernel":
        from repro.kernels.mpnn_mp import ops as mp_ops
        step = lambda hh: mp_ops.message_pass(hh, edge_mat, adj)
    else:
        step = lambda hh: message_pass_ref(hh, edge_mat, adj)

    for _ in range(cfg.message_steps):
        m = step(h)                                            # (B,N,Hd)
        hm = jnp.concatenate([h, m], axis=-1)
        z = jax.nn.sigmoid(hm @ params["gru_wz"])
        r = jax.nn.sigmoid(hm @ params["gru_wr"])
        cand = jnp.tanh(jnp.concatenate([r * h, m], axis=-1) @ params["gru_wh"])
        h = ((1 - z) * h + z * cand) * mask[..., None]

    pooled = jnp.sum(h * mask[..., None], axis=1)              # (B,Hd)
    x = jax.nn.relu(pooled @ params["ro_w1"] + params["ro_b1"])
    return (x @ params["ro_w2"] + params["ro_b2"])[..., 0]     # (B,)


def ensemble_apply(stacked_params, atoms, bonds, mask, cfg: MPNNConfig,
                   impl: str = "ref"):
    """stacked_params leaves have a leading (E,) axis.
    Returns (E, B) predictions."""
    fn = lambda p: mpnn_forward(p, atoms, bonds, mask, cfg, impl)
    return jax.vmap(fn)(stacked_params)


def ucb(preds, kappa: float = 2.0):
    """Upper confidence bound over ensemble predictions (E, B) -> (B,)."""
    mean = jnp.mean(preds, axis=0)
    std = jnp.std(preds, axis=0)
    return mean + kappa * std


def mpnn_loss(params, batch, cfg: MPNNConfig):
    pred = mpnn_forward(params, batch["atoms"], batch["bonds"],
                        batch["mask"], cfg)
    err = pred - batch["y"]
    return jnp.mean(jnp.square(err))
