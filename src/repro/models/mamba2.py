"""Mamba2 block (zamba2 backbone): projections + causal conv + SSD scan.

Layout follows the Mamba2 paper: a fused input projection producing
(z gate, x, B, C, dt), a depthwise causal conv over (x, B, C), the SSD
recurrence (repro.kernels.mamba2_ssd), a gated RMSNorm and an output
projection.  Decode carries {conv_state, ssm_state}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.mamba2_ssd import ops as ssd_ops
from repro.models.layers import rmsnorm

# log-decay clamp: keeps exp() terms finite in every implementation
MIN_LOG_A = -12.0


def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or d_inner // 64          # head dim P = 64 by default
    P = d_inner // H
    G = 1                                        # single B/C group
    return d_inner, H, P, G


def mamba_params(mk, cfg: ModelConfig, stacked=()):
    d = cfg.d_model
    d_inner, H, P, G = mamba_dims(cfg)
    N, W = cfg.ssm_state, cfg.ssm_conv
    conv_ch = d_inner + 2 * G * N
    proj_out = 2 * d_inner + 2 * G * N + H      # z, x, B, C, dt
    lead = tuple("layer" for _ in stacked)
    return {
        "in_proj": mk.param(stacked + (d, proj_out),
                            lead + ("embed", "ssm_inner"), fan_in=d),
        "conv_w": mk.param(stacked + (W, conv_ch),
                           lead + ("conv", "ssm_inner"), scale=0.5),
        "conv_b": mk.param(stacked + (conv_ch,),
                           lead + ("ssm_inner",), init="zeros"),
        "a_log": mk.param(stacked + (H,), lead + ("ssm_heads",), init="ones"),
        "dt_bias": mk.param(stacked + (H,), lead + ("ssm_heads",), init="zeros"),
        "d_skip": mk.param(stacked + (H,), lead + ("ssm_heads",), init="ones"),
        "norm": mk.param(stacked + (d_inner,), lead + ("ssm_inner",),
                         init="ones"),
        "out_proj": mk.param(stacked + (d_inner, d),
                             lead + ("ssm_inner", "embed"), fan_in=d_inner),
    }


def _split_proj(zxbcdt, cfg):
    d_inner, H, P, G = mamba_dims(cfg)
    N = cfg.ssm_state
    z, xin, b, c, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + G * N, 2 * d_inner + 2 * G * N],
        axis=-1)
    return z, xin, b, c, dt


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv. x (B,L,C), w (W,C). Returns (y, new_state)
    where state is the last W-1 inputs (B, W-1, C)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # (B, L+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else pad[:, :0]
    return y + b, new_state


def _ssm_inputs(params, xin_c, b_c, c_c, dt_raw, cfg):
    """Common post-conv plumbing: activations + dt/decay computation."""
    d_inner, H, P, G = mamba_dims(cfg)
    N = cfg.ssm_state
    xin_c = jax.nn.silu(xin_c)
    b_c = jax.nn.silu(b_c)
    c_c = jax.nn.silu(c_c)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (...,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))              # (H,) < 0
    log_a = jnp.maximum(dt * a, MIN_LOG_A)                         # (...,H)
    return xin_c, b_c, c_c, dt, log_a


def mamba_block(params, x, cfg: ModelConfig, cache=None):
    """x (B,L,D) -> (y (B,L,D), new_cache).

    cache: None (training/prefill from scratch) or
    {"conv": (B,W-1,C), "ssm": (B,H,P,N)}; L may be 1 (decode) or more.
    """
    B, L, D = x.shape
    d_inner, H, P, G = mamba_dims(cfg)
    N = cfg.ssm_state
    cd = jnp.dtype(cfg.compute_dtype)

    zxbcdt = jnp.einsum("bld,dk->blk", x, params["in_proj"].astype(cd))
    z, xin, b, c, dt_raw = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(
        conv_in, params["conv_w"].astype(cd), params["conv_b"].astype(cd),
        conv_state)
    xin_c, b_c, c_c = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)
    xin_c, b_c, c_c, dt, log_a = _ssm_inputs(params, xin_c, b_c, c_c,
                                             dt_raw, cfg)

    xh = (xin_c.astype(jnp.float32).reshape(B, L, H, P)
          * dt[..., None]).astype(cd)                       # dt-scaled input
    bg = b_c.reshape(B, L, G, N)
    cg = c_c.reshape(B, L, G, N)
    s0 = cache["ssm"] if cache is not None else None

    if L == 1 and cache is not None:
        y, s = ssd_ops.ssd_step(xh[:, 0], log_a[:, 0], bg[:, 0], cg[:, 0], s0)
        y = y[:, None]
    else:
        impl = "kernel" if cfg.attn_impl == "kernel" else "ref"
        y, s = ssd_ops.ssd(xh, log_a.astype(cd), bg, cg, s0, impl=impl,
                           chunk=min(cfg.attn_chunk, 128),
                           unroll=cfg.scan_unroll)

    y = y.astype(jnp.float32) + (params["d_skip"].astype(jnp.float32)[:, None]
                                 * xin_c.astype(jnp.float32).reshape(B, L, H, P))
    y = y.reshape(B, L, d_inner).astype(cd)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, params["out_proj"].astype(cd))
    new_cache = {"conv": new_conv, "ssm": s} if cache is not None else None
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, layers: int, dtype=None):
    d_inner, H, P, G = mamba_dims(cfg)
    N, W = cfg.ssm_state, cfg.ssm_conv
    conv_ch = d_inner + 2 * G * N
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    return {
        "conv": jnp.zeros((layers, batch, W - 1, conv_ch), dt),
        "ssm": jnp.zeros((layers, batch, H, P, N), jnp.float32),
    }
