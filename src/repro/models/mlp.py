"""Dense gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import axisenv


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_params(mk, cfg: ModelConfig, stacked=(), d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    lead = tuple("layer" for _ in stacked)
    return {
        "wi_gate": mk.param(stacked + (d, f), lead + ("embed", "ff"), fan_in=d),
        "wi_up": mk.param(stacked + (d, f), lead + ("embed", "ff"), fan_in=d),
        "wo": mk.param(stacked + (f, d), lead + ("ff", "embed"), fan_in=f),
    }


def mlp(params, x, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    act = _act(cfg.act)
    if cfg.fuse_ffn:
        # single fused input matmul: better MXU utilization, one gather of x.
        # Fused along a new leading axis (not concatenated along ff): the
        # ff dim of both halves stays aligned with its TP shards, so the
        # gate/up split is always shard-local (concat+split across the
        # sharded ff dim miscompiles under GSPMD on some XLA builds).
        wi = jnp.stack([params["wi_gate"], params["wi_up"]]).astype(cd)
        gu = jnp.einsum("bsd,gdf->gbsf", x, wi)
        g, u = gu[0], gu[1]
    else:
        g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(cd))
        u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(cd))
    h = axisenv.constrain(act(g) * u, "batch", None, "model")
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(cd))
    return axisenv.constrain(out, "batch",
                             "seq" if cfg.seq_parallel else None, None)
