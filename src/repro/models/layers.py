"""Shared building blocks: the param-maker pattern, norms, RoPE, embeddings.

Every module defines its parameters exactly once via a ``params(mk, cfg)``
function.  The *maker* ``mk`` decides what is produced:

- ``InitMaker``  -> initialized jnp arrays (used under ``jax.eval_shape`` for
  abstract shapes too),
- ``SpecMaker``  -> logical-axis tuples, later resolved to PartitionSpecs by
  ``repro.distributed.sharding``.

This guarantees shapes and shardings can never drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Param makers
# ---------------------------------------------------------------------------


class InitMaker:
    """Creates initialized parameters; deterministic in call order."""

    def __init__(self, key, param_dtype):
        self.key = key
        self.dtype = param_dtype

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(self, shape, axes, init="normal", scale=None, fan_in=None):
        del axes
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "normal":
            if scale is None:
                fi = fan_in if fan_in is not None else (shape[-2] if len(shape) >= 2 else shape[-1])
                scale = 1.0 / np.sqrt(max(fi, 1))
            return (scale * jax.random.truncated_normal(
                self._next_key(), -2.0, 2.0, shape, jnp.float32)).astype(self.dtype)
        raise ValueError(init)


class SpecMaker:
    """Returns the logical-axis annotation for each parameter."""

    def __init__(self):
        pass

    def param(self, shape, axes, init="normal", scale=None, fan_in=None):
        del init, scale, fan_in
        assert len(axes) == len(shape), f"axes {axes} vs shape {shape}"
        return tuple(axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_params(mk, dim, stacked=()):
    return {"scale": mk.param(stacked + (dim,), tuple("layer" for _ in stacked) + ("embed",), init="ones")}


def rmsnorm(params, x, eps):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_head(scale, x, eps):
    """Per-head RMS norm (qwen3 qk-norm): scale shape (head_dim,)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    exponent = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def rope_cos_sin(positions, head_dim, theta, mrope_sections=None):
    """cos/sin tables.

    positions: (B, S) int32, or (3, B, S) for M-RoPE (temporal, height, width).
    Returns cos, sin with shape (B, S, head_dim/2), float32.
    """
    inv = jnp.asarray(rope_freqs(head_dim, theta))  # (hd/2,)
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,hd/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3,B,S) positions"
        secs = mrope_sections
        assert sum(secs) == head_dim // 2, (secs, head_dim)
        parts = []
        start = 0
        for i, sec in enumerate(secs):
            p = positions[i][..., None].astype(jnp.float32)  # (B,S,1)
            parts.append(p * inv[start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # (B,S,hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, head_dim); cos/sin: (B, S, head_dim/2). Split-half convention."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_params(mk, cfg: ModelConfig):
    p = {"embed": mk.param((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           scale=1.0, fan_in=cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = mk.param((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return p


def embed(params, tokens, cfg: ModelConfig):
    h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.emb_scale:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    return h


def unembed(params, h, cfg: ModelConfig):
    from repro.distributed import axisenv
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))
    logits = axisenv.constrain(logits, "batch", None, "model")
    if cfg.final_logit_softcap:
        cap = cfg.final_logit_softcap
        logits = cap * jnp.tanh(logits.astype(jnp.float32) / cap)
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, mask=None):
    """Token-level CE. logits (B,S,V) any float dtype; labels (B,S) int32.

    Computed in f32 with the logsumexp trick; safe for sharded vocab (GSPMD
    inserts the reductions).  Returns (mean_loss, token_count).
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.sum(nll * mask)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    return total / count, count
