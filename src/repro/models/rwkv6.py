"""RWKV6 "Finch" block: token-shift time mix with data-dependent decay
(WKV recurrence in repro.kernels.rwkv6_scan) + channel mix FFN.

Simplifications vs. the released RWKV6 (noted in DESIGN.md): the five
token-shift mixing coefficients are static learned vectors (the low-rank
data-dependent ddlerp is applied only to the decay, which is the part that
changes the recurrence class); the decay LoRA has rank cfg.rwkv_lora.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.models.layers import rmsnorm

MIN_LOG_W = -12.0
RWKV_LORA = 64


def rwkv_dims(cfg: ModelConfig):
    K = cfg.rwkv_head_size
    H = cfg.d_model // K
    return H, K


def rwkv_time_mix_params(mk, cfg: ModelConfig, stacked=()):
    d = cfg.d_model
    H, K = rwkv_dims(cfg)
    lead = tuple("layer" for _ in stacked)
    p = {}
    for name in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        p[name] = mk.param(stacked + (d,), lead + ("embed",), init="zeros")
    for name in ("wr", "wk", "wv", "wg", "wo"):
        p[name] = mk.param(stacked + (d, d), lead + ("embed", "embed2"),
                           fan_in=d)
    p["w0"] = mk.param(stacked + (d,), lead + ("embed",), init="zeros")
    p["w_lora_a"] = mk.param(stacked + (d, RWKV_LORA),
                             lead + ("embed", "lora"), fan_in=d)
    p["w_lora_b"] = mk.param(stacked + (RWKV_LORA, d),
                             lead + ("lora", "embed"), scale=0.01)
    p["u"] = mk.param(stacked + (H, K), lead + ("heads", "head_dim"),
                      init="zeros")
    p["ln_x"] = mk.param(stacked + (d,), lead + ("embed",), init="ones")
    return p


def rwkv_channel_mix_params(mk, cfg: ModelConfig, stacked=()):
    d, f = cfg.d_model, cfg.d_ff
    lead = tuple("layer" for _ in stacked)
    return {
        "mu_k": mk.param(stacked + (d,), lead + ("embed",), init="zeros"),
        "wk": mk.param(stacked + (d, f), lead + ("embed", "ff"), fan_in=d),
        "wv": mk.param(stacked + (f, d), lead + ("ff", "embed"), fan_in=f),
    }


def _token_shift(x, prev):
    """shifted[t] = x[t-1]; position 0 takes `prev` (B,1,D) or zeros."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu            # lerp between current and shifted


def rwkv_time_mix(params, x, cfg: ModelConfig, cache=None):
    """x (B,L,D) -> (y, new_cache); cache = {"shift": (B,1,D), "state": (B,H,K,K)}."""
    B, L, D = x.shape
    H, K = rwkv_dims(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    xs = _token_shift(x, cache["shift"] if cache is not None else None)

    def proj(name):
        return jnp.einsum("bld,de->ble",
                          _mix(x, xs, params["mu_" + name[1]]),
                          params[name].astype(cd))

    r = proj("wr").reshape(B, L, H, K)
    k = proj("wk").reshape(B, L, H, K)
    v = proj("wv").reshape(B, L, H, K)
    g = jax.nn.silu(proj("wg"))

    # data-dependent decay (the Finch contribution): w = exp(-exp(...))
    xw = _mix(x, xs, params["mu_w"])
    lora = jnp.einsum("bld,dr->blr", xw, params["w_lora_a"].astype(cd))
    lora = jnp.einsum("blr,rd->bld", jnp.tanh(lora),
                      params["w_lora_b"].astype(cd))
    w_raw = params["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    log_w = jnp.maximum(-jnp.exp(w_raw), MIN_LOG_W).reshape(B, L, H, K)

    state = cache["state"] if cache is not None else None
    if L == 1 and cache is not None:
        y, s = wkv_ops.wkv6_step(r[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
                                 params["u"], state)
        y = y[:, None]
    else:
        impl = "kernel" if cfg.attn_impl == "kernel" else "ref"
        y, s = wkv_ops.wkv6(r, k, v, log_w.astype(cd), params["u"], state,
                            impl=impl, chunk=min(cfg.attn_chunk, 64),
                            unroll=cfg.scan_unroll)

    y = y.reshape(B, L, D)
    y = rmsnorm({"scale": params["ln_x"]}, y, cfg.norm_eps) * g
    out = jnp.einsum("bld,de->ble", y, params["wo"].astype(cd))
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1:], "state": s}
    return out, new_cache


def rwkv_channel_mix(params, x, cfg: ModelConfig, cache=None):
    """Squared-ReLU channel mix; cache = {"shift": (B,1,D)}."""
    cd = jnp.dtype(cfg.compute_dtype)
    xs = _token_shift(x, cache["shift"] if cache is not None else None)
    kx = _mix(x, xs, params["mu_k"])
    h = jnp.square(jax.nn.relu(
        jnp.einsum("bld,df->blf", kx, params["wk"].astype(cd))))
    out = jnp.einsum("blf,fd->bld", h, params["wv"].astype(cd))
    new_cache = {"shift": x[:, -1:]} if cache is not None else None
    return out, new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int, layers: int, dtype=None):
    H, K = rwkv_dims(cfg)
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    return {
        "tm_shift": jnp.zeros((layers, batch, 1, cfg.d_model), dt),
        "cm_shift": jnp.zeros((layers, batch, 1, cfg.d_model), dt),
        "state": jnp.zeros((layers, batch, H, K, K), jnp.float32),
    }
