"""Decoder-only transformer stacks for every assigned LM family.

One parameter tree + one apply path per family, all scanned over layers with
``lax.scan`` (stacked parameters, small HLO).  Families:

- dense    : [granite-20b, qwen3-8b, internlm2-1.8b, qwen2-vl backbone]
- gemma2   : alternating local/global attention, sandwich norms, softcaps
- moe      : [kimi-k2, llama4-scout] capacity-routed expert FFN
- zamba2   : Mamba2 backbone + one *shared* attention block every N layers
- rwkv     : RWKV6 attention-free time mix / channel mix

The same block functions serve train (no cache), prefill (collect cache) and
decode (consume + update cache); caches are stacked over layers so they flow
through the scans as xs/ys.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2, rwkv6
from repro.models.layers import rmsnorm, rmsnorm_params, rope_cos_sin
from repro.models.mlp import mlp, mlp_params
from repro.models.moe import moe_ffn, moe_params


# ---------------------------------------------------------------------------
# Block parameter trees
# ---------------------------------------------------------------------------


def dense_block_params(mk, cfg: ModelConfig, stacked=(), moe: bool = False,
                       cross: bool = False):
    p = {
        "ln1": rmsnorm_params(mk, cfg.d_model, stacked),
        "attn": attn.attention_params(mk, cfg, stacked),
        "ln2": rmsnorm_params(mk, cfg.d_model, stacked),
        "ffn": (moe_params(mk, cfg, stacked) if moe
                else mlp_params(mk, cfg, stacked)),
    }
    if cross:
        p["ln_cross"] = rmsnorm_params(mk, cfg.d_model, stacked)
        p["cross"] = attn.attention_params(mk, cfg, stacked, cross=True)
    if cfg.post_norm:
        p["ln1_post"] = rmsnorm_params(mk, cfg.d_model, stacked)
        p["ln2_post"] = rmsnorm_params(mk, cfg.d_model, stacked)
    return p


def rwkv_block_params(mk, cfg: ModelConfig, stacked=()):
    return {
        "ln1": rmsnorm_params(mk, cfg.d_model, stacked),
        "tmix": rwkv6.rwkv_time_mix_params(mk, cfg, stacked),
        "ln2": rmsnorm_params(mk, cfg.d_model, stacked),
        "cmix": rwkv6.rwkv_channel_mix_params(mk, cfg, stacked),
    }


def mamba_block_params(mk, cfg: ModelConfig, stacked=()):
    return {
        "ln": rmsnorm_params(mk, cfg.d_model, stacked),
        "mamba": mamba2.mamba_params(mk, cfg, stacked),
    }


# ---------------------------------------------------------------------------
# Block applications.  All return (h, new_cache, aux_loss).
# ---------------------------------------------------------------------------


def _maybe_post(p, name, y, cfg):
    return rmsnorm(p[name], y, cfg.norm_eps) if cfg.post_norm else y


def _residual(h, cfg):
    """Between-block residual-stream sharding.  With seq_parallel the token
    dimension is sharded over the model axis, so the per-sub-layer
    all-reduce of TP partial sums becomes reduce-scatter (+ all-gather at
    the next projection): half the wire bytes, and norms/elementwise run
    1/TP as wide."""
    from repro.distributed import axisenv
    if cfg.seq_parallel:
        return axisenv.constrain(h, "batch", "seq", None)
    return axisenv.constrain(h, "batch", None, None)


def apply_dense_block(p, h, cfg: ModelConfig, *, cos, sin, window=None,
                      causal=True, cache=None, cur_len=None, enc_kv=None,
                      collect_cache=False):
    a_in = rmsnorm(p["ln1"], h, cfg.norm_eps)
    if collect_cache:
        q, k, v = attn.project_qkv(p["attn"], a_in, cfg, cos, sin)
        o = attn.attend(q, k, v, cfg=cfg, causal=causal, window=window)
        a_out = attn.output_proj(p["attn"], o, cfg)
        new_cache = {"k": k, "v": v}
    else:
        a_out, new_cache = attn.self_attention(
            p["attn"], a_in, cfg, cos=cos, sin=sin, causal=causal,
            window=window, cache=cache, cur_len=cur_len)
    h = _residual(h + _maybe_post(p, "ln1_post", a_out, cfg), cfg)

    if enc_kv is not None:
        c_in = rmsnorm(p["ln_cross"], h, cfg.norm_eps)
        h = _residual(h + attn.cross_attention(p["cross"], c_in, enc_kv,
                                               cfg), cfg)

    m_in = rmsnorm(p["ln2"], h, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "router" in p["ffn"]:
        m_out, aux = moe_ffn(p["ffn"], m_in, cfg)
    else:
        m_out = mlp(p["ffn"], m_in, cfg)
    h = _residual(h + _maybe_post(p, "ln2_post", m_out, cfg), cfg)
    return h, new_cache, aux


def apply_rwkv_block(p, h, cfg: ModelConfig, cache=None):
    tm_cache = cm_cache = None
    if cache is not None:
        tm_cache = {"shift": cache["tm_shift"], "state": cache["state"]}
        cm_cache = {"shift": cache["cm_shift"]}
    t_out, tm_new = rwkv6.rwkv_time_mix(
        p["tmix"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg, tm_cache)
    h = h + t_out
    c_out, cm_new = rwkv6.rwkv_channel_mix(
        p["cmix"], rmsnorm(p["ln2"], h, cfg.norm_eps), cfg, cm_cache)
    h = h + c_out
    new_cache = None
    if cache is not None:
        new_cache = {"tm_shift": tm_new["shift"], "state": tm_new["state"],
                     "cm_shift": cm_new["shift"]}
    return h, new_cache, jnp.zeros((), jnp.float32)


def apply_mamba_block(p, h, cfg: ModelConfig, cache=None):
    m_out, new_cache = mamba2.mamba_block(
        p["mamba"], rmsnorm(p["ln"], h, cfg.norm_eps), cfg, cache)
    return h + m_out, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Stacks.  params["blocks"] layout depends on the family (see builders).
# ---------------------------------------------------------------------------


def _ckpt(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "policy":
        # save matmul outputs; recompute only cheap elementwise work in the
        # backward pass (vs "block", which recomputes the full forward)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def stack_params(mk, cfg: ModelConfig):
    """Stacked block parameters for the decoder stack of `cfg`."""
    L = cfg.num_layers
    if cfg.rwkv:
        return {"rwkv": rwkv_block_params(mk, cfg, stacked=(L,))}
    if cfg.family == "hybrid":
        ae = max(cfg.attn_every, 1)
        groups, tail = divmod(L, ae)
        p = {"mamba_main": mamba_block_params(mk, cfg, stacked=(groups, ae)),
             "shared_attn": dense_block_params(mk, cfg)}
        if tail:
            p["mamba_tail"] = mamba_block_params(mk, cfg, stacked=(tail,))
        return p
    if cfg.local_global_period:
        per = cfg.local_global_period
        assert L % per == 0, (L, per)
        return {"lg": dense_block_params(mk, cfg, stacked=(L // per, per),
                                         moe=cfg.is_moe)}
    return {"uniform": dense_block_params(mk, cfg, stacked=(L,),
                                          moe=cfg.is_moe)}


def _scan_uniform(params, h, cfg, apply_fn, cache, collect):
    """Generic scan over a (L, ...)-stacked block group."""
    def body(carry, xs):
        h, aux = carry
        p, c = xs
        h, new_c, a = apply_fn(p, h, c)
        return (h, aux + a), new_c

    body = _ckpt(body, cfg)
    L = jax.tree.leaves(params)[0].shape[0]
    xs = (params, cache)
    if cache is None and not collect:
        xs = (params, None)
    (h, aux), new_cache = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                       xs, length=L,
                                       unroll=cfg.scan_unroll)
    return h, new_cache, aux


def run_stack(params, h, cfg: ModelConfig, *, cos, sin, cache=None,
              cur_len=None, collect_cache=False):
    """Run the decoder stack.  Returns (h, new_cache, aux_loss).

    cache trees are stacked over layers; `collect_cache` makes a fresh cache
    from a full forward pass (prefill)."""
    if cfg.rwkv:
        def app(p, x, c):
            return apply_rwkv_block(p, x, cfg, c)
        if collect_cache:
            cache = rwkv6.init_rwkv_cache(cfg, h.shape[0], cfg.num_layers)
        return _scan_uniform(params["rwkv"], h, cfg, app, cache,
                             collect_cache)

    if cfg.family == "hybrid":
        return _run_zamba_stack(params, h, cfg, cos=cos, sin=sin, cache=cache,
                                cur_len=cur_len, collect_cache=collect_cache)

    if cfg.local_global_period:
        return _run_local_global_stack(params, h, cfg, cos=cos, sin=sin,
                                       cache=cache, cur_len=cur_len,
                                       collect_cache=collect_cache)

    def app(p, x, c):
        return apply_dense_block(p, x, cfg, cos=cos, sin=sin, cache=c,
                                 cur_len=cur_len,
                                 collect_cache=collect_cache)
    return _scan_uniform(params["uniform"], h, cfg, app, cache, collect_cache)


def _run_local_global_stack(params, h, cfg, *, cos, sin, cache, cur_len,
                            collect_cache):
    """gemma2: period-P pattern, sub-layer i of each step has its own window.
    Convention: the *last* layer of each period is global; the rest local."""
    per = cfg.local_global_period
    windows = [cfg.sliding_window] * (per - 1) + [None]

    def body(carry, xs):
        h, aux = carry
        p, c = xs
        new_cs = []
        for i in range(per):
            pi = jax.tree.map(lambda t: t[i], p)
            ci = None if c is None else jax.tree.map(lambda t: t[i], c)
            h, nc, a = apply_dense_block(
                pi, h, cfg, cos=cos, sin=sin, window=windows[i], cache=ci,
                cur_len=cur_len, collect_cache=collect_cache)
            aux = aux + a
            new_cs.append(nc)
        stacked_c = (None if new_cs[0] is None else
                     jax.tree.map(lambda *t: jnp.stack(t), *new_cs))
        return (h, aux), stacked_c

    body = _ckpt(body, cfg)
    n_steps = cfg.num_layers // per
    # reshape stacked caches (L, ...) -> (n_steps, per, ...)
    c_in = cache
    if cache is not None:
        c_in = jax.tree.map(
            lambda t: t.reshape((n_steps, per) + t.shape[1:]), cache)
    (h, aux), new_cache = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (params["lg"], c_in),
        length=n_steps, unroll=cfg.scan_unroll)
    if new_cache is not None:
        new_cache = jax.tree.map(
            lambda t: t.reshape((cfg.num_layers,) + t.shape[2:]), new_cache)
    return h, new_cache, aux


def _run_zamba_stack(params, h, cfg, *, cos, sin, cache, cur_len,
                     collect_cache):
    """zamba2: groups of `attn_every` Mamba2 blocks, each followed by the
    SHARED attention block (same params, per-application KV cache)."""
    ae = max(cfg.attn_every, 1)
    groups, tail = divmod(cfg.num_layers, ae)
    shared_p = params["shared_attn"]

    def mamba_app(p, x, c):
        return apply_mamba_block(p, x, cfg, c)

    def group_body(carry, xs):
        h, aux = carry
        p_grp, c_mamba, c_attn = xs
        h, new_m, a1 = _scan_uniform(p_grp, h, cfg, mamba_app, c_mamba,
                                     collect_cache)
        h, new_a, a2 = apply_dense_block(
            shared_p, h, cfg, cos=cos, sin=sin, cache=c_attn,
            cur_len=cur_len, collect_cache=collect_cache)
        return (h, aux + a1 + a2), (new_m, new_a)

    group_body = _ckpt(group_body, cfg)

    c_mamba_main = c_mamba_tail = c_attn = None
    if cache is not None:
        c_mamba_main = jax.tree.map(
            lambda t: t[:groups * ae].reshape((groups, ae) + t.shape[1:]),
            cache["mamba"])
        if tail:
            c_mamba_tail = jax.tree.map(lambda t: t[groups * ae:],
                                        cache["mamba"])
        c_attn = cache["attn"]
    elif collect_cache:
        # prefill: mamba states start from zeros (block updates them);
        # attention KV is *collected* fresh, so no input cache is needed.
        B = h.shape[0]
        full = mamba2.init_mamba_cache(cfg, B, cfg.num_layers)
        c_mamba_main = jax.tree.map(
            lambda t: t[:groups * ae].reshape((groups, ae) + t.shape[1:]),
            full)
        if tail:
            c_mamba_tail = jax.tree.map(lambda t: t[groups * ae:], full)
        c_attn = None

    (h, aux), (new_m, new_a) = jax.lax.scan(
        group_body, (h, jnp.zeros((), jnp.float32)),
        (params["mamba_main"], c_mamba_main, c_attn), length=groups,
        unroll=cfg.scan_unroll)

    new_mamba = jax.tree.map(
        lambda t: t.reshape((groups * ae,) + t.shape[2:]), new_m)
    if tail:
        h, new_tail, a3 = _scan_uniform(params["mamba_tail"], h, cfg,
                                        mamba_app, c_mamba_tail,
                                        collect_cache)
        aux = aux + a3
        if new_tail is not None:
            new_mamba = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), new_mamba, new_tail)

    new_cache = None
    if new_mamba is not None and new_a is not None:
        new_cache = {"mamba": new_mamba, "attn": new_a}
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache pytree for the decoder stack (stacked over layers)."""
    if cfg.rwkv:
        return rwkv6.init_rwkv_cache(cfg, batch, cfg.num_layers)
    if cfg.family == "hybrid":
        ae = max(cfg.attn_every, 1)
        groups = cfg.num_layers // ae
        return {
            "mamba": mamba2.init_mamba_cache(cfg, batch, cfg.num_layers),
            "attn": attn.init_kv_cache(cfg, batch, max_len, groups),
        }
    return attn.init_kv_cache(cfg, batch, max_len, cfg.num_layers)


def positions_for(cfg: ModelConfig, batch: int, seq: int, offset=0):
    pos = jnp.arange(seq)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def rope_tables(cfg: ModelConfig, positions):
    if cfg.rwkv:
        return None, None
    return rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta,
                        cfg.mrope_sections)
