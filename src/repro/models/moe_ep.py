"""Expert-parallel MoE via shard_map + explicit all_to_all (moe_impl="ep_a2a").

Why: under pure GSPMD the gather-based dispatch/combine lowers to
all-gathers of the (E, C, D) expert buffers plus a giant scatter-add
all-reduce in the backward pass (~94 GB/layer/device wire for kimi-k2 at
train_4k -- measured, see EXPERIMENTS.md §Perf).  The canonical EP lowering
moves only the routed token activations, twice:

  tokens (seq-sharded over the model axis)
    -> route locally -> per-destination-rank send buffers
    -> all_to_all over "model" (dispatch)
    -> local capacity dispatch to this rank's E/TP experts -> expert FFN
    -> results written back into the mirrored slot layout
    -> all_to_all back (combine) -> weighted sum per token.

Per-layer wire: 2 x T_local*K*D*bf16 per device (~0.9 GB for kimi) instead
of ~94 GB.  Works with the seq-parallel residual layout (tokens already
sharded over "model"); requires S % TP == 0, falling back to the GSPMD path
otherwise (e.g. decode with S=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import axisenv
from repro.distributed.compat import shard_map
from repro.models.mlp import _act


def _round_up(x, m):
    return -(-x // m) * m


def _positions_in_group(group_ids, num_groups, capacity):
    """group_ids (A,) -> (pos (A,), keep (A,)): slot within each group,
    assignment order = index order."""
    oh = jax.nn.one_hot(group_ids, num_groups, dtype=jnp.int32)   # (A,G)
    pos = jnp.cumsum(oh, axis=0) - oh
    pos = jnp.sum(pos * oh, axis=-1)
    return pos, pos < capacity


def moe_ep_a2a(params, x, cfg: ModelConfig, mesh, batch_axes):
    """x (B, S, D) -> (y, aux). Requires an ambient mesh with a "model"
    axis dividing S and cfg.num_experts."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_token
    tp = int(mesh.shape["model"])
    E_loc = E // tp
    S_loc = S // tp
    dp = 1
    for a in (batch_axes or ()):
        dp *= int(mesh.shape[a])
    T_loc = (B // dp) * S_loc                      # per-DEVICE tokens
    A = T_loc * K                                  # local assignments
    # capacity of each rank->rank send lane and of each local expert
    C_send = _round_up(int(A / tp * cfg.capacity_factor) + 1, 8)
    C_e = _round_up(int(tp * C_send / E_loc * cfg.capacity_factor) + 1, 8)
    cd = jnp.dtype(cfg.compute_dtype)
    act = _act(cfg.act)

    bax = tuple(batch_axes) if batch_axes else None
    all_axes = tuple(mesh.shape.keys())
    in_specs = (
        P(bax, "model", None),                     # x: seq-sharded
        P(None, None),                             # router (replicated)
        P("model", None, None),                    # wi_gate
        P("model", None, None),                    # wi_up
        P("model", None, None),                    # wo
    )
    out_specs = (P(bax, "model", None), P())

    def body(x_loc, router, wi_g, wi_u, wo):
        # x_loc: (B_loc, S_loc, D) -- per-device block
        b_loc = x_loc.shape[0]
        t_loc = b_loc * S_loc
        a_loc = t_loc * K
        xt = x_loc.reshape(t_loc, D)

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        gates = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(gates, K)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

        # Switch aux loss over local tokens (mean of means == global mean)
        oh = jax.nn.one_hot(topi, E, dtype=jnp.float32)
        aux = E * jnp.sum(jnp.mean(jnp.sum(oh, 1), 0) * jnp.mean(gates, 0))

        # ---- dispatch: build per-destination-rank send lanes ----
        e_flat = topi.reshape(a_loc)                       # global expert id
        dest = e_flat // E_loc                             # owning rank
        pos, keep = _positions_in_group(dest, tp, C_send)
        tok = jnp.broadcast_to(
            jnp.arange(t_loc, dtype=jnp.int32)[:, None],
            (t_loc, K)).reshape(a_loc)

        slot_tok = jnp.full((tp, C_send), t_loc, jnp.int32)
        slot_tok = slot_tok.at[dest, jnp.where(keep, pos, C_send)].set(
            tok, mode="drop")
        slot_eid = jnp.full((tp, C_send), E_loc, jnp.int32)
        slot_eid = slot_eid.at[dest, jnp.where(keep, pos, C_send)].set(
            (e_flat % E_loc).astype(jnp.int32), mode="drop")

        xt_pad = jnp.concatenate(
            [xt, jnp.zeros((1, D), xt.dtype)], axis=0)
        send_x = jnp.take(xt_pad, slot_tok, axis=0).astype(cd)  # (tp,Cs,D)

        recv_x = jax.lax.all_to_all(
            send_x.reshape(tp * C_send, D), "model", 0, 0, tiled=True
        ).reshape(tp, C_send, D)
        recv_eid = jax.lax.all_to_all(
            slot_eid.reshape(tp * C_send), "model", 0, 0, tiled=True
        ).reshape(tp, C_send)

        # ---- local capacity dispatch to my E_loc experts ----
        r_eid = recv_eid.reshape(tp * C_send)
        valid = r_eid < E_loc
        epos, ekeep = _positions_in_group(
            jnp.where(valid, r_eid, E_loc), E_loc + 1, C_e)
        ekeep = ekeep & valid
        eslot = jnp.full((E_loc, C_e), tp * C_send, jnp.int32)
        eslot = eslot.at[jnp.where(valid, r_eid, E_loc),
                         jnp.where(ekeep, epos, C_e)].set(
            jnp.arange(tp * C_send, dtype=jnp.int32), mode="drop")
        rx_pad = jnp.concatenate(
            [recv_x.reshape(tp * C_send, D),
             jnp.zeros((1, D), recv_x.dtype)], axis=0)
        xe = jnp.take(rx_pad, eslot, axis=0)               # (E_loc, C_e, D)

        # ---- expert FFN (this rank's experts) ----
        g = jnp.einsum("ecd,edf->ecf", xe, wi_g.astype(cd))
        u = jnp.einsum("ecd,edf->ecf", xe, wi_u.astype(cd))
        ye = jnp.einsum("ecf,efd->ecd", act(g) * u, wo.astype(cd))

        # ---- write results back into the mirrored recv layout ----
        flat = jnp.where(ekeep, jnp.where(valid, r_eid, 0) * C_e + epos,
                         E_loc * C_e)
        ye_pad = jnp.concatenate(
            [ye.reshape(E_loc * C_e, D),
             jnp.zeros((1, D), ye.dtype)], axis=0)
        back = jnp.take(ye_pad, flat, axis=0)              # (tp*C_send, D)

        ret = jax.lax.all_to_all(back, "model", 0, 0, tiled=True)

        # ---- combine ----
        ret_flat = jnp.concatenate(
            [ret, jnp.zeros((1, D), ret.dtype)], axis=0)
        a_idx = jnp.where(keep, dest * C_send + pos, tp * C_send)
        y_sel = jnp.take(ret_flat, a_idx, axis=0)          # (a_loc, D)
        w = (topw.reshape(a_loc, 1)
             * keep.reshape(a_loc, 1)).astype(y_sel.dtype)
        y = jnp.sum((y_sel * w).reshape(t_loc, K, D), axis=1)
        aux = jax.lax.pmean(aux, all_axes)                 # global mean
        return y.reshape(b_loc, S_loc, D).astype(x_loc.dtype), aux

    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(x, params["router"], params["wi_gate"], params["wi_up"], params["wo"])
    return y, aux
