"""Encoder-decoder transformer (seamless-m4t backbone).

The modality frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, D).  The decoder is a standard
causal stack with per-layer cross-attention to the encoder output; decode
carries {self-KV cache, precomputed cross-KV}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import rmsnorm, rmsnorm_params
from repro.models.transformer import (_ckpt, _scan_uniform, apply_dense_block,
                                      dense_block_params)


def encdec_stack_params(mk, cfg: ModelConfig):
    return {
        "encoder": dense_block_params(mk, cfg, stacked=(cfg.encoder_layers,)),
        "enc_norm": rmsnorm_params(mk, cfg.d_model),
        "decoder": dense_block_params(mk, cfg, stacked=(cfg.num_layers,),
                                      cross=True),
    }


def encode(params, frames, cfg: ModelConfig, *, cos, sin):
    """frames (B, S_enc, D) -> encoder output (B, S_enc, D)."""
    def app(p, x, c):
        del c
        h, _, aux = apply_dense_block(p, x, cfg, cos=cos, sin=sin,
                                      causal=False)
        return h, None, aux

    h, _, _ = _scan_uniform(params["encoder"], frames, cfg, app, None, False)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute per-decoder-layer cross K/V: leaves (L, B, S_enc, KVH, hd)."""
    def body(_, p):
        kv = attn.encode_cross_kv(p["cross"], enc_out, cfg)
        return None, kv

    _, kv = jax.lax.scan(body, None, params["decoder"],
                         unroll=cfg.scan_unroll)
    return kv


def run_decoder(params, h, cfg: ModelConfig, *, cos, sin, enc_kv,
                cache=None, cur_len=None, collect_cache=False):
    """Decoder stack with cross-attention. enc_kv leaves (L, B, S_enc, ...)."""
    def body(carry, xs):
        hh, aux = carry
        p, ekv, c = xs
        hh, new_c, a = apply_dense_block(
            p, hh, cfg, cos=cos, sin=sin, cache=c, cur_len=cur_len,
            enc_kv=ekv, collect_cache=collect_cache)
        return (hh, aux + a), new_c

    body = _ckpt(body, cfg)
    (h, aux), new_cache = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)),
        (params["decoder"], enc_kv, cache), length=cfg.num_layers,
        unroll=cfg.scan_unroll)
    return h, new_cache, aux
