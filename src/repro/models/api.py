"""Public model API: one set of entry points for every assigned arch.

    params  = init_params(cfg, key)            # concrete jnp arrays
    specs   = param_specs(cfg)                 # logical-axis tuples (same tree)
    logits, aux = forward(params, cfg, batch)  # train / full-sequence
    loss, metrics = loss_fn(params, cfg, batch)
    logits, cache = prefill(params, cfg, batch)
    logits, cache = decode_step(params, cfg, cache, tokens, cur_len)

batch keys: "tokens" (B,S) int32 OR "embeds" (B,S,D) for stub-frontend archs
(vlm/audio); "labels" (B,S); "positions" optional ((3,B,S) for M-RoPE);
enc-dec additionally takes "frames" (B,S_enc,D) for the encoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import (InitMaker, SpecMaker, embed,
                                 embedding_params, rmsnorm, rmsnorm_params,
                                 softmax_cross_entropy, unembed)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def model_params(mk, cfg: ModelConfig):
    p = {
        "tok": embedding_params(mk, cfg),
        "final_norm": rmsnorm_params(mk, cfg.d_model),
    }
    if cfg.is_encdec:
        p["stack"] = encdec.encdec_stack_params(mk, cfg)
    else:
        p["stack"] = transformer.stack_params(mk, cfg)
    return p


def init_params(cfg: ModelConfig, key):
    mk = InitMaker(key, jnp.dtype(cfg.param_dtype))
    return model_params(mk, cfg)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct tree without allocating (for dry-runs)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_specs(cfg: ModelConfig):
    return model_params(SpecMaker(), cfg)


# ---------------------------------------------------------------------------
# Embedding front
# ---------------------------------------------------------------------------


def _embed_input(params, cfg, batch):
    if batch.get("embeds") is not None:
        h = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
    else:
        h = embed(params["tok"], batch["tokens"], cfg)
    B, S = h.shape[0], h.shape[1]
    pos = batch.get("positions")
    if pos is None:
        pos = transformer.positions_for(cfg, B, S)
    cos, sin = transformer.rope_tables(cfg, pos)
    return h, cos, sin


# ---------------------------------------------------------------------------
# Full-sequence forward (training) and loss
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch):
    h, cos, sin = _embed_input(params, cfg, batch)
    if cfg.is_encdec:
        frames = batch["frames"].astype(h.dtype)
        epos = transformer.positions_for(cfg, frames.shape[0], frames.shape[1])
        ecos, esin = transformer.rope_tables(cfg, epos)
        enc_out = encdec.encode(params["stack"], frames, cfg,
                                cos=ecos, sin=esin)
        ekv = encdec.cross_kv(params["stack"], enc_out, cfg)
        h, _, aux = encdec.run_decoder(params["stack"], h, cfg, cos=cos,
                                       sin=sin, enc_kv=ekv)
    else:
        h, _, aux = transformer.run_stack(params["stack"], h, cfg,
                                          cos=cos, sin=sin)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["tok"], h, cfg)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch)
    ce, count = softmax_cross_entropy(logits, batch["labels"],
                                      batch.get("loss_mask"))
    loss = ce
    if cfg.is_moe:
        loss = loss + cfg.router_aux_coef * aux
    metrics = {"ce": ce, "aux": aux, "tokens": count}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, batch):
    """Full-sequence forward that also materializes the decode cache.
    Returns (last-position logits (B,V), cache)."""
    h, cos, sin = _embed_input(params, cfg, batch)
    if cfg.is_encdec:
        frames = batch["frames"].astype(h.dtype)
        epos = transformer.positions_for(cfg, frames.shape[0], frames.shape[1])
        ecos, esin = transformer.rope_tables(cfg, epos)
        enc_out = encdec.encode(params["stack"], frames, cfg,
                                cos=ecos, sin=esin)
        ekv = encdec.cross_kv(params["stack"], enc_out, cfg)
        h, self_kv, _ = encdec.run_decoder(params["stack"], h, cfg, cos=cos,
                                           sin=sin, enc_kv=ekv,
                                           collect_cache=True)
        cache = {"self": self_kv, "cross": ekv}
    else:
        h, cache, _ = transformer.run_stack(params["stack"], h, cfg, cos=cos,
                                            sin=sin, collect_cache=True)
    h = rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    logits = unembed(params["tok"], h, cfg)
    return logits[:, 0], cache


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_len):
    """One decode step. tokens (B,1); cur_len scalar int array: number of
    positions already in the cache. Returns (logits (B,V), new_cache)."""
    batch = {"tokens": tokens}
    B = tokens.shape[0]
    pos = transformer.positions_for(cfg, B, 1, offset=cur_len)
    h = embed(params["tok"], tokens, cfg)
    cos, sin = transformer.rope_tables(cfg, pos)
    if cfg.is_encdec:
        h, self_kv, _ = encdec.run_decoder(
            params["stack"], h, cfg, cos=cos, sin=sin,
            enc_kv=cache["cross"], cache=cache["self"], cur_len=cur_len)
        new_cache = {"self": self_kv, "cross": cache["cross"]}
    else:
        h, new_cache, _ = transformer.run_stack(
            params["stack"], h, cfg, cos=cos, sin=sin, cache=cache,
            cur_len=cur_len)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["tok"], h, cfg)
    return logits[:, 0], new_cache


def grow_cache(cfg: ModelConfig, cache, new_capacity: int):
    """Pad attention KV caches along the sequence axis to `new_capacity`
    (SSM/conv/shift states are length-independent and pass through)."""
    def pad_kv(kv):
        def pad(t):
            cap = t.shape[2]
            if cap >= new_capacity:
                return t
            widths = [(0, 0)] * t.ndim
            widths[2] = (0, new_capacity - cap)
            return jnp.pad(t, widths)
        return {"k": pad(kv["k"]), "v": pad(kv["v"])}

    if cfg.is_encdec:
        return {"self": pad_kv(cache["self"]), "cross": cache["cross"]}
    if cfg.rwkv:
        return cache
    if cfg.family == "hybrid":
        return {"mamba": cache["mamba"], "attn": pad_kv(cache["attn"])}
    return pad_kv(cache)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    if cfg.is_encdec:
        hd = cfg.resolved_head_dim
        dt = jnp.dtype(cfg.compute_dtype)
        cross = {
            "k": jnp.zeros((cfg.num_layers, batch, enc_len,
                            cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((cfg.num_layers, batch, enc_len,
                            cfg.num_kv_heads, hd), dt),
        }
        return {"self": transformer.init_cache(cfg, batch, max_len),
                "cross": cross}
    return transformer.init_cache(cfg, batch, max_len)
