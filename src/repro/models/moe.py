"""Mixture-of-Experts FFN with capacity-based expert-parallel dispatch.

Three reference implementations, selected by ``cfg.moe_impl``:

- ``dropping`` (default): scatter/gather dispatch.  Tokens are grouped per
  batch row; each row scatters its token *indices* into an (E, C) slot table
  (cheap int scatter), gathers token activations into (E, C, D), runs the
  per-expert FFN, and gathers results back.  Cost is O(T*D) data movement +
  the expert GEMMs -- no O(T*E*C*D) one-hot einsums.  Over-capacity tokens
  are dropped (the residual stream passes them through), matching GShard /
  Switch semantics.
- ``einsum``: the classic GShard one-hot dispatch/combine einsums.  Exact
  same semantics as ``dropping``; costs O(T*E*C*D) so only viable for tiny
  shapes.  Used as the oracle in tests.
- ``dense``: computes every expert for every token and mixes with router
  weights (no capacity, no drops).  Tiny smoke configs only.

A grouped-matmul Pallas kernel (repro.kernels.moe_gmm) implements the sorted
per-expert FFN for the TPU path (``moe_impl="gmm"``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import axisenv
from repro.models.mlp import _act


def moe_params(mk, cfg: ModelConfig, stacked=()):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    lead = tuple("layer" for _ in stacked)
    return {
        "router": mk.param(stacked + (d, e), lead + ("embed", "experts"),
                           fan_in=d),
        "wi_gate": mk.param(stacked + (e, d, f),
                            lead + ("experts", "embed", "ff"), fan_in=d),
        "wi_up": mk.param(stacked + (e, d, f),
                          lead + ("experts", "embed", "ff"), fan_in=d),
        "wo": mk.param(stacked + (e, f, d),
                       lead + ("experts", "ff", "embed"), fan_in=f),
    }


def _router(params, x, cfg: ModelConfig):
    """x (..., D) -> (gates (...,E) f32, topw (...,k), topi (...,k))."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, cfg.num_experts_per_token)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    return gates, topw, topi


def aux_load_balance_loss(gates, topi, num_experts: int):
    """Switch-style load-balancing loss: E * sum_e f_e * P_e."""
    oh = jax.nn.one_hot(topi, num_experts, dtype=jnp.float32)  # (...,k,E)
    frac_tokens = jnp.mean(jnp.sum(oh, axis=-2).reshape(-1, num_experts),
                           axis=0)
    frac_prob = jnp.mean(gates.reshape(-1, num_experts), axis=0)
    return num_experts * jnp.sum(frac_tokens * frac_prob)


def _expert_ffn(params, xe, cfg: ModelConfig):
    """xe (E, C, D) -> (E, C, D); per-expert gated MLP."""
    cd = jnp.dtype(cfg.compute_dtype)
    act = _act(cfg.act)
    g = jnp.einsum("ecd,edf->ecf", xe, params["wi_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", xe, params["wi_up"].astype(cd))
    return jnp.einsum("ecf,efd->ecd", act(g) * u, params["wo"].astype(cd))


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(max(1, round(cfg.num_experts_per_token * tokens_per_group
                         / cfg.num_experts * cfg.capacity_factor)))
    if c > 128:
        c = -(-c // 128) * 128       # lane-friendly rounding when large
    return c


def _route_positions(topi, cfg: ModelConfig, capacity: int):
    """topi (S, K) expert ids -> (pos (S,K) slot-in-expert, keep (S,K)).

    Assignment priority is k-slot major: every token's top-1 choice wins
    capacity before any token's top-2 choice, matching GShard."""
    S, K = topi.shape
    E = cfg.num_experts
    oh = jax.nn.one_hot(topi, E, dtype=jnp.int32)             # (S,K,E)
    oh_km = jnp.transpose(oh, (1, 0, 2)).reshape(K * S, E)
    pos_km = jnp.cumsum(oh_km, axis=0) - oh_km
    pos = pos_km.reshape(K, S, E).transpose(1, 0, 2)          # (S,K,E)
    pos = jnp.sum(pos * oh, axis=-1)                          # (S,K)
    keep = pos < capacity
    return pos, keep


def moe_dropping(params, x, cfg: ModelConfig):
    """Scatter/gather dispatch. x (B,S,D) -> (y (B,S,D), aux_loss).

    Written vmap-free (batched scatters/gathers) so the expert-parallel
    sharding constraints on the (B, E, C, D) expert buffers apply: with
    experts on the "model" axis and batch on "data", GSPMD lowers the
    gather -> expert-FFN -> gather-back path as the canonical EP
    all-to-all pair instead of replicating expert inputs."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_token
    C = _capacity(cfg, S)
    cd = jnp.dtype(cfg.compute_dtype)

    gates, topw, topi = _router(params, x, cfg)               # (B,S,E/K)
    aux = aux_load_balance_loss(gates, topi, E)

    pos, keep = jax.vmap(lambda t: _route_positions(t, cfg, C))(topi)
    e_flat = topi.reshape(B, S * K)
    p_flat = jnp.where(keep, pos, C).reshape(B, S * K)        # C = dropped
    tok_flat = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, K)
    ).reshape(B, S * K)
    b_idx = jnp.broadcast_to(
        jnp.arange(B, dtype=jnp.int32)[:, None], (B, S * K))

    # (B, E, C) slot table of source-token indices; empty slots -> S (OOB)
    slots = jnp.full((B, E, C), S, jnp.int32)
    slots = slots.at[b_idx, e_flat, p_flat].set(tok_flat, mode="drop")

    # batched gather into expert slots: empty slots hold the OOB index S and
    # read zeros via fill-mode.  (The old pad-row concat grew the token
    # axis, which is sharded over "model" under seq-parallelism -- the same
    # concat+split-across-a-sharded-dim pattern that miscompiled fuse_ffn
    # under GSPMD; a fill-mode gather never changes the sharded shape.)
    xe = jnp.take_along_axis(
        x, slots.reshape(B, E * C)[..., None], axis=1,
        mode="fill", fill_value=0,
    ).reshape(B, E, C, D).astype(cd)
    xe = axisenv.constrain(xe, "batch", "model", None, None)  # EP a2a here
    ye = _expert_ffn_batched(params, xe, cfg)                 # (B,E,C,D)
    ye = axisenv.constrain(ye, "batch", "model", None, None)

    # combine: gather each kept assignment's slot back (a2a back here).
    # Dropped assignments point at the OOB index E*C and read zeros via
    # fill-mode -- the E*C axis merges the "model"-sharded expert dim, so
    # appending a pad row here was the second instance of the sharded-dim
    # concat pattern.
    yk = ye.reshape(B, E * C, D)
    flat_idx = jnp.where(keep.reshape(B, S * K),
                         e_flat * C + p_flat, E * C)          # OOB = dropped
    y_sel = jnp.take_along_axis(yk, flat_idx[..., None], axis=1,
                                mode="fill", fill_value=0)
    w = (topw.reshape(B, S * K, 1)
         * keep.reshape(B, S * K, 1)).astype(y_sel.dtype)
    y = jnp.sum((y_sel * w).reshape(B, S, K, D), axis=2)
    y = axisenv.constrain(y, "batch", None, None)
    return y.astype(x.dtype), aux


def _expert_ffn_batched(params, xe, cfg: ModelConfig):
    """xe (B, E, C, D) -> (B, E, C, D); per-expert gated MLP."""
    cd = jnp.dtype(cfg.compute_dtype)
    act = _act(cfg.act)
    g = jnp.einsum("becd,edf->becf", xe, params["wi_gate"].astype(cd))
    u = jnp.einsum("becd,edf->becf", xe, params["wi_up"].astype(cd))
    return jnp.einsum("becf,efd->becd", act(g) * u,
                      params["wo"].astype(cd))


def moe_einsum(params, x, cfg: ModelConfig):
    """GShard one-hot dispatch/combine einsums (oracle; tiny shapes only)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_token
    C = _capacity(cfg, S)
    cd = jnp.dtype(cfg.compute_dtype)
    gates, topw, topi = _router(params, x, cfg)
    aux = aux_load_balance_loss(gates, topi, E)

    def row(x_row, topi_row, topw_row):
        pos, keep = _route_positions(topi_row, cfg, C)
        ohf = (jax.nn.one_hot(topi_row, E) * keep[..., None])  # (S,K,E)
        slot = jax.nn.one_hot(pos, C)                          # (S,K,C)
        dispatch = jnp.einsum("ske,skc->sec", ohf, slot)
        combine = jnp.einsum("ske,skc,sk->sec", ohf, slot,
                             topw_row.astype(jnp.float32))
        xe = jnp.einsum("sd,sec->ecd", x_row.astype(jnp.float32),
                        dispatch).astype(cd)
        ye = _expert_ffn(params, xe, cfg)
        return jnp.einsum("ecd,sec->sd", ye.astype(jnp.float32), combine)

    y = jax.vmap(row)(x, topi, topw)
    return y.astype(x.dtype), aux


def moe_dense(params, x, cfg: ModelConfig):
    """Exact MoE: every expert for every token (tiny configs only)."""
    B, S, D = x.shape
    E = cfg.num_experts
    xt = x.reshape(B * S, D)
    gates, topw, topi = _router(params, xt, cfg)
    aux = aux_load_balance_loss(gates, topi, E)
    mix = jnp.sum(jax.nn.one_hot(topi, E) * topw[..., None], axis=1)  # (T,E)
    cd = jnp.dtype(cfg.compute_dtype)
    xe = jnp.broadcast_to(xt[None], (E,) + xt.shape).astype(cd)
    ye = _expert_ffn(params, xe, cfg)                         # (E,T,D)
    y = jnp.einsum("etd,te->td", ye.astype(jnp.float32), mix)
    return y.reshape(B, S, D).astype(x.dtype), aux


def moe_gmm(params, x, cfg: ModelConfig):
    """Sorted grouped-matmul path backed by the Pallas kernel."""
    from repro.kernels.moe_gmm import ops as gmm_ops
    return gmm_ops.moe_ffn(params, x, cfg)


def moe_ep(params, x, cfg: ModelConfig):
    """shard_map expert-parallel all_to_all path (perf lever); falls back
    to the GSPMD scatter/gather path when the mesh/shape does not fit
    (no model axis, S or E not divisible, decode with S=1)."""
    env = axisenv._env()
    mesh = env.get("mesh") if env else None
    tp = int(mesh.shape.get("model", 1)) if mesh is not None else 1
    if (mesh is None or tp <= 1 or x.shape[1] % tp
            or cfg.num_experts % tp):
        return moe_dropping(params, x, cfg)
    from repro.models import moe_ep as ep
    return ep.moe_ep_a2a(params, x, cfg, mesh, env["batch"])


def moe_ffn(params, x, cfg: ModelConfig):
    impl = {"dropping": moe_dropping, "einsum": moe_einsum,
            "dense": moe_dense, "gmm": moe_gmm, "ep_a2a": moe_ep}
    return impl[cfg.moe_impl](params, x, cfg)
