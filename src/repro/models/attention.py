"""Attention: GQA projections + chunked flash-style reference math.

The reference implementation (`mha_reference`) is a blockwise online-softmax
attention in pure jnp: it never materializes the full (Sq, Sk) score matrix,
skips fully-masked KV chunks at *trace* time (so sliding-window layers cost
only their window), and supports:

- grouped-query attention (num_kv_heads < num_heads),
- causal masking with a query position offset (decode),
- static sliding windows (gemma2 local layers),
- attention-logit softcapping (gemma2),
- dynamic valid-length masking (decode against a partially filled cache).

The TPU Pallas kernel (`repro.kernels.flash_attention`) implements the same
contract; `attend` dispatches on ``cfg.attn_impl``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import axisenv
from repro.models.layers import apply_rope, rmsnorm_head

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def attention_params(mk, cfg: ModelConfig, stacked=(), cross: bool = False):
    """Projection weights for one attention module (self or cross)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    lead = tuple("layer" for _ in stacked)
    p = {
        "wq": mk.param(stacked + (d, nh, hd), lead + ("embed", "heads", "head_dim"),
                       fan_in=d),
        "wk": mk.param(stacked + (d, nkv, hd), lead + ("embed", "kv_heads", "head_dim"),
                       fan_in=d),
        "wv": mk.param(stacked + (d, nkv, hd), lead + ("embed", "kv_heads", "head_dim"),
                       fan_in=d),
        "wo": mk.param(stacked + (nh, hd, d), lead + ("heads", "head_dim", "embed"),
                       fan_in=nh * hd),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = mk.param(stacked + (hd,), lead + ("head_dim",), init="ones")
        p["k_norm"] = mk.param(stacked + (hd,), lead + ("head_dim",), init="ones")
    return p


# ---------------------------------------------------------------------------
# Core math: blockwise online-softmax attention
# ---------------------------------------------------------------------------


def _chunk_alive(causal: bool, window: Optional[int],
                 q0: int, q1: int, k0: int, k1: int) -> bool:
    """Static reachability of a (q-chunk, kv-chunk) pair. Positions are
    absolute (q already offset). q/k ranges are [q0, q1), [k0, k1)."""
    if causal and k0 > q1 - 1:
        return False            # chunk entirely in the future
    if window is not None and q0 - (k1 - 1) >= window:
        return False            # chunk entirely beyond the look-back window
    return True


def mha_reference(
    q: jax.Array,              # (B, Sq, H, hd)
    k: jax.Array,              # (B, Sk, KVH, hd)
    v: jax.Array,              # (B, Sk, KVH, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,       # static look-back window (None = full)
    softcap: Optional[float] = None,
    q_offset=0,                         # static int OR scalar array (decode)
    valid_len=None,                     # scalar array: kv positions < valid are real
    chunk_q: int = 1024,
    chunk_k: int = 1024,
) -> jax.Array:
    """Blockwise attention; returns (B, Sq, H, hd) in q.dtype.

    GQA is handled by *repeating* K/V to the full head count instead of
    reshaping Q to (KVH, G, hd): the repeat keeps the head axis intact, so
    a "model"-sharded head dimension propagates through every einsum with
    no resharding (the (KVH, G) reshape misaligns GSPMD shard boundaries
    whenever KVH < the mesh axis).  The repeat is a chunk-local transient.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    scale = hd ** -0.5
    dyn_offset = not isinstance(q_offset, int)
    # For static chunk skipping when the offset is dynamic (decode), the only
    # safe static bound is "q is somewhere in [0, inf)" -> no skipping unless
    # windowed; decode Sq is tiny so this costs nothing.
    static_q0 = 0 if dyn_offset else q_offset

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos_all = jnp.arange(Sk)

    def kv_chunk(t, k0, k1):
        c = t[:, k0:k1]
        return jnp.repeat(c, G, axis=2) if G > 1 else c   # (B,ck,H,hd)

    # decode fast path: tiny Sq, single pass over the whole cache
    if Sq <= 8:
        ke, ve = kv_chunk(kf, 0, Sk), kv_chunk(vf, 0, Sk)
        s = jnp.einsum("bihd,bjhd->bhij", qf, ke)         # (B,H,Sq,Sk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = jnp.arange(Sq) + q_offset
        mask = jnp.ones((Sq, Sk), bool)
        if causal:
            mask &= kpos_all[None, :] <= qpos[:, None]
        if window is not None:
            mask &= qpos[:, None] - kpos_all[None, :] < window
        if valid_len is not None:
            mask &= kpos_all[None, :] < valid_len
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhij,bjhd->bihd", p, ve)
        return o.astype(q.dtype)

    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    n_q, n_k = -(-Sq // cq), -(-Sk // ck)
    assert Sq % cq == 0 and Sk % ck == 0, "seq must divide chunk sizes"

    out_chunks = []
    for iq in range(n_q):
        q0s = static_q0 + iq * cq                    # static lower bound
        qc = qf[:, iq * cq:(iq + 1) * cq]            # (B,cq,H,hd)
        qpos = jnp.arange(iq * cq, (iq + 1) * cq) + q_offset  # (cq,) abs
        m = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, cq), jnp.float32)
        acc = jnp.zeros((B, cq, H, hd), jnp.float32)
        for ik in range(n_k):
            k0, k1 = ik * ck, (ik + 1) * ck
            if not dyn_offset and not _chunk_alive(
                    causal, window, q0s, q0s + cq, k0, k1):
                continue
            kc, vc = kv_chunk(kf, k0, k1), kv_chunk(vf, k0, k1)
            s = jnp.einsum("bihd,bjhd->bhij", qc, kc)    # (B,H,cq,ck)
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            kpos = kpos_all[k0:k1]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            if valid_len is not None:
                mask &= kpos[None, :] < valid_len
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = (acc * jnp.transpose(corr, (0, 2, 1))[..., None]
                   + jnp.einsum("bhij,bjhd->bihd", p, vc))
            m = m_new
        l_t = jnp.transpose(l, (0, 2, 1))                 # (B,cq,H)
        out_chunks.append(acc / jnp.maximum(l_t, 1e-30)[..., None])
    o = jnp.concatenate(out_chunks, axis=1) if n_q > 1 else out_chunks[0]
    return o.astype(q.dtype)                              # (B,Sq,H,hd)


def attend(q, k, v, *, cfg: ModelConfig, causal=True, window=None,
           q_offset=0, valid_len=None):
    """Dispatch between the jnp reference and the Pallas TPU kernel."""
    if cfg.attn_impl == "kernel":
        from repro.kernels.flash_attention import ops as fa_ops
        # The Pallas kernel covers the static-offset self/cross attention
        # cases; decode (dynamic offset, Sq=1) always uses the reference
        # (it is a tiny GEMV-like op where a kernel buys nothing).
        if isinstance(q_offset, int) and valid_len is None and q.shape[1] > 1:
            return fa_ops.flash_attention(
                q, k, v, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap, q_offset=q_offset)
    return mha_reference(
        q, k, v, causal=causal, window=window,
        softcap=cfg.attn_logit_softcap, q_offset=q_offset,
        valid_len=valid_len, chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk)


# ---------------------------------------------------------------------------
# Full attention block step (projections + rope + cache + attention)
# ---------------------------------------------------------------------------


def _project_kv(params, x, cfg: ModelConfig):
    """Fused K/V input matmul: one gather of x feeds both projections.
    Fused along a new leading axis (wk/wv have identical shapes), NOT
    concatenated along heads: the kv-head axis of both halves stays
    aligned with its "kv" shards, so the k/v split is always shard-local.
    Fusing Q in as well would require a concat across the *differing* head
    counts (H vs KVH under GQA) -- the concat+split-across-a-sharded-dim
    pattern that miscompiled fuse_ffn under GSPMD -- so Q stays separate.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.fuse_kv:
        wkv = jnp.stack([params["wk"], params["wv"]]).astype(cd)
        kv = jnp.einsum("bsd,gdhk->gbshk", x, wkv)
        return kv[0], kv[1]
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cd))
    return k, v


def project_qkv(params, x, cfg: ModelConfig, cos=None, sin=None):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KVH,hd); applies qk-norm + rope."""
    cd = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cd))
    k, v = _project_kv(params, x, cfg)
    q = axisenv.constrain(q, "batch", None, "model", None)
    k = axisenv.constrain(k, "batch", None, "kv", None)
    v = axisenv.constrain(v, "batch", None, "kv", None)
    if "q_norm" in params:
        q = rmsnorm_head(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_head(params["k_norm"], k, cfg.norm_eps)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def output_proj(params, o, cfg: ModelConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    o = axisenv.constrain(o, "batch", None, "model", None)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(cd))
    # under sequence parallelism the partial-sum reduction lands directly
    # as a reduce-scatter onto the token-sharded residual layout
    return axisenv.constrain(out, "batch",
                             "seq" if cfg.seq_parallel else None, None)


def self_attention(params, x, cfg: ModelConfig, *, cos, sin, causal=True,
                   window=None, cache=None, cur_len=None):
    """One self-attention application.

    cache: None (full-sequence) or dict {k, v} of (B, S_max, KVH, hd) arrays.
    cur_len: scalar array; when cache is given, the new tokens are written at
    [cur_len, cur_len + Sq) and attention sees positions < cur_len + Sq.
    Returns (out (B,Sq,D), new_cache).
    """
    q, k_new, v_new = project_qkv(params, x, cfg, cos, sin)
    if cache is None:
        o = attend(q, k_new, v_new, cfg=cfg, causal=causal, window=window)
        return output_proj(params, o, cfg), None
    # decode / incremental path
    B = x.shape[0]
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, _as_idx(cur_len), 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, _as_idx(cur_len), 0, 0))
    k = axisenv.constrain(k, "batch", None, "kv", None)
    v = axisenv.constrain(v, "batch", None, "kv", None)
    valid = cur_len + x.shape[1]
    o = attend(q, k, v, cfg=cfg, causal=True, window=window,
               q_offset=cur_len, valid_len=valid)
    return output_proj(params, o, cfg), {"k": k, "v": v}


def cross_attention(params, x, enc_kv, cfg: ModelConfig):
    """Decoder cross-attention; enc_kv = {k, v} precomputed from encoder."""
    cd = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cd))
    o = attend(q, enc_kv["k"], enc_kv["v"], cfg=cfg, causal=False)
    return output_proj(params, o, cfg)


def encode_cross_kv(params, enc_out, cfg: ModelConfig):
    k, v = _project_kv(params, enc_out, cfg)
    return {"k": k, "v": v}


def _as_idx(i):
    return i if isinstance(i, int) else i.astype(jnp.int32)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, layers: int,
                  dtype=None):
    """Stacked-over-layers KV cache pytree."""
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    shape = (layers, batch, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
