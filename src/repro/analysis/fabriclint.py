"""fabriclint: AST passes encoding the dispatch fabric's concurrency
invariants.

Every regression class shipped so far was a concurrency invariant
violated silently -- an unguarded lazy init splitting the replication
FIFO, a leaked daemon thread, a non-idempotent op behind
reconnect-resend.  Each pass below encodes one such invariant as a
mechanical check over ``src/repro/core/**``:

- **wait-needs-predicate** -- ``Condition.wait()`` must sit inside a
  ``while``-predicate loop (spurious wakeups, stolen notifies) or carry
  a timeout bound.
- **idempotent-retry-registry** -- a ``retry=True`` frame send may only
  name ops declared in ``repro.analysis.idempotent_ops.IDEMPOTENT_OPS``
  (each with a one-line justification).  Sites whose header is built
  dynamically declare their op set with ``# fabriclint: retry-ops=a,b``.
- **guarded-lazy-init** -- an attribute assigned under
  ``if self._x is None`` must be inside a ``with <lock>:`` block, or two
  racing threads each build (and one leaks) the resource.
- **thread-lifecycle** -- ``Thread(daemon=True).start()`` requires a
  reachable stop/sentinel/join path (a stop/close/shutdown method or a
  ``join`` in the same class; a stop-flag or sentinel check in the
  target function for module-level spawns).
- **monotonic-deadlines** -- no ``time.time()`` in fabric code; leases,
  stragglers and timeouts use ``repro.utils.timing.now()`` (monotonic),
  immune to wall-clock steps.
- **frame-header-hygiene** -- wire headers are plain dicts with string
  keys and primitive values; envelope payload bytes ride the frame body
  and are relayed verbatim, never re-pickled (single-pickle-per-hop).
- **span-name-registry** -- every ``obs.span``/``obs.counter``/... call
  in fabric code names a literal declared in
  ``repro.observability.names``; an undeclared or dynamic name silently
  fragments the merged timeline and the metrics rollup.

False positives are suppressed in place with a justified pragma::

    pickle.loads(payload)   # fabriclint: skip=frame-header-hygiene -- why

Findings not suppressed and not in ``analysis/baseline.json`` fail
``--check``; the baseline only ratchets down (``--update-baseline``
rewrites it to the current finding set).
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.idempotent_ops import IDEMPOTENT_OPS
from repro.observability.names import METRIC_NAMES, SPAN_NAMES

REPO_ROOT = Path(__file__).resolve().parents[3]
# the fabric's concurrency surface: the dispatch core plus the serving
# subsystem (the shard's serve loop, heartbeat thread, and lease
# bookkeeping live under the same invariants)
DEFAULT_TARGETS = (REPO_ROOT / "src" / "repro" / "core",
                   REPO_ROOT / "src" / "repro" / "serving")
DEFAULT_TARGET = DEFAULT_TARGETS[0]      # kept for callers by name
DEFAULT_BASELINE = REPO_ROOT / "analysis" / "baseline.json"

# relay modules: code that forwards envelopes it must not re-pickle
RELAY_MODULES = ("transport/broker.py", "transport/proc.py",
                 "transport/local.py", "cluster/federation.py")

_SKIP_RE = re.compile(r"#\s*fabriclint:\s*skip=([\w-]+)\s*--\s*\S")
_RETRY_OPS_RE = re.compile(r"#\s*fabriclint:\s*retry-ops=([\w,\s]+)")
_LOCKISH_RE = re.compile(r"lock|cond|mutex", re.IGNORECASE)
_STOPPISH_RE = re.compile(r"stop|cancel|shutdown|done|sentinel",
                          re.IGNORECASE)


@dataclass(frozen=True)
class Finding:
    pass_name: str
    file: str                   # repo-relative path
    line: int
    message: str

    def render(self) -> str:
        return f"{self.pass_name} {self.file}:{self.line} {self.message}"

    def key(self) -> tuple:
        # line numbers drift with unrelated edits; identity is
        # (pass, file, message)
        return (self.pass_name, self.file, self.message)


# ---------------------------------------------------------------------------
# per-file context
# ---------------------------------------------------------------------------


def _terminal_name(node: ast.AST) -> Optional[str]:
    """`self._meta_lock` -> '_meta_lock', `q.cond` -> 'cond', `ev` -> 'ev'."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_threading_ctor(node: ast.AST, kinds: Sequence[str]) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id == "threading" and f.attr in kinds:
        return True
    return isinstance(f, ast.Name) and f.id in kinds


class FileCtx:
    """One parsed file plus the derived name sets the passes share."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._fl_parent = node          # type: ignore[attr-defined]
        # names assigned from threading.Condition(...) / Lock / RLock
        # anywhere in the module -- cheap local "type inference"
        self.condition_names: Set[str] = set()
        self.lock_names: Set[str] = set()
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Assign):
                continue
            is_cond = _is_threading_ctor(node.value, ("Condition",))
            is_lock = is_cond or _is_threading_ctor(
                node.value, ("Lock", "RLock"))
            if not is_lock:
                continue
            for tgt in node.targets:
                name = _terminal_name(tgt)
                if name:
                    self.lock_names.add(name)
                    if is_cond:
                        self.condition_names.add(name)

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = getattr(node, "_fl_parent", None)
        while cur is not None:
            yield cur
            cur = getattr(cur, "_fl_parent", None)

    def enclosing(self, node: ast.AST, *types) -> Optional[ast.AST]:
        for anc in self.ancestors(node):
            if isinstance(anc, types):
                return anc
        return None

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def suppressed(self, pass_name: str, lineno: int) -> bool:
        """A `# fabriclint: skip=<pass> -- <reason>` pragma on the line
        or the line above suppresses; the reason text is mandatory."""
        for ln in (lineno, lineno - 1):
            m = _SKIP_RE.search(self.line_text(ln))
            if m and m.group(1) == pass_name:
                return True
        return False

    def retry_ops_pragma(self, node: ast.Call) -> Optional[List[str]]:
        """`# fabriclint: retry-ops=a,b,c` near a dynamic-header retry
        site names the ops that can flow through it."""
        end = getattr(node, "end_lineno", node.lineno) or node.lineno
        for ln in range(node.lineno - 1, end + 1):
            m = _RETRY_OPS_RE.search(self.line_text(ln))
            if m:
                return [op.strip() for op in m.group(1).split(",")
                        if op.strip()]
        return None


# ---------------------------------------------------------------------------
# passes
# ---------------------------------------------------------------------------

_FN_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
              ast.ClassDef)


def _find(ctx: FileCtx, pass_name: str, node: ast.AST,
          message: str) -> Finding:
    return Finding(pass_name, ctx.rel, node.lineno, message)


def pass_wait_needs_predicate(ctx: FileCtx) -> List[Finding]:
    """A bare ``cond.wait()`` outside a while-predicate loop loses
    wakeups forever: spurious wakeups and notify_all races mean a single
    wait can return with the predicate still false."""
    out = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "wait"):
            continue
        recv = _terminal_name(node.func.value)
        if recv not in ctx.condition_names:
            continue                    # Event.wait etc: no predicate needed
        timeout_args = list(node.args) + [
            kw.value for kw in node.keywords if kw.arg == "timeout"]
        bounded = any(
            not (isinstance(a, ast.Constant) and a.value is None)
            for a in timeout_args)
        if bounded:
            continue
        in_while = False
        for anc in ctx.ancestors(node):
            if isinstance(anc, ast.While):
                in_while = True
                break
            if isinstance(anc, _FN_SCOPES):
                break
        if not in_while:
            out.append(_find(
                ctx, "wait-needs-predicate", node,
                f"Condition.wait() on {recv!r} is not inside a while-"
                "predicate loop and has no timeout bound; a spurious "
                "wakeup or stolen notify blocks it forever"))
    return out


def _header_ops(node: ast.Call) -> Optional[List[Finding]]:
    """Extract constant 'op' values from dict-literal args; None when no
    literal header is present."""
    ops = []
    exprs = list(node.args) + [kw.value for kw in node.keywords
                               if kw.arg != "retry"]
    found_header = False
    for arg in exprs:
        if not isinstance(arg, ast.Dict):
            continue
        for k, v in zip(arg.keys, arg.values):
            if isinstance(k, ast.Constant) and k.value == "op":
                found_header = True
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    ops.append(v.value)
                else:
                    ops.append(None)    # dynamic op inside a literal header
    return ops if found_header else None


def pass_idempotent_retry_registry(ctx: FileCtx) -> List[Finding]:
    """reconnect-resend may double-apply an op that landed before the
    connection died; only ops argued idempotent in IDEMPOTENT_OPS (one
    justification line each) may be sent with ``retry=True``."""
    out = []
    registry_hint = ("declare it in repro/analysis/idempotent_ops.py with "
                     "a one-line idempotency justification, or drop "
                     "retry=True")
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        retry_kw = next((kw for kw in node.keywords if kw.arg == "retry"),
                        None)
        if retry_kw is None or not (
                isinstance(retry_kw.value, ast.Constant)
                and retry_kw.value.value is True):
            continue                    # retry=retry forwarding etc
        ops = _header_ops(node)
        if ops is None:
            ops = ctx.retry_ops_pragma(node)
        if ops is None:
            out.append(_find(
                ctx, "idempotent-retry-registry", node,
                "retry=True with a dynamic header: name the ops that flow "
                "through this site with '# fabriclint: retry-ops=a,b'"))
            continue
        for op in ops:
            if op is None:
                out.append(_find(
                    ctx, "idempotent-retry-registry", node,
                    "retry=True header has a non-literal 'op' value; "
                    "use '# fabriclint: retry-ops=a,b' to name it"))
            elif op not in IDEMPOTENT_OPS:
                out.append(_find(
                    ctx, "idempotent-retry-registry", node,
                    f"op {op!r} is sent with retry=True but is not in "
                    f"the IDEMPOTENT_OPS registry; {registry_hint}"))
    return out


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def pass_guarded_lazy_init(ctx: FileCtx) -> List[Finding]:
    """`if self._x is None: self._x = ...` without a lock lets two
    threads each build the resource -- one copy leaks while callers keep
    using both (the PR-5 split-replication-FIFO bug class)."""
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.If):
            continue
        lazy = set()
        for cmp_ in ast.walk(node.test):
            if (isinstance(cmp_, ast.Compare)
                    and len(cmp_.ops) == 1
                    and isinstance(cmp_.ops[0], ast.Is)
                    and isinstance(cmp_.comparators[0], ast.Constant)
                    and cmp_.comparators[0].value is None):
                attr = _self_attr(cmp_.left)
                if attr:
                    lazy.add(attr)
        if not lazy:
            continue
        assigned = set()
        for stmt in node.body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Assign):
                    for tgt in sub.targets:
                        attr = _self_attr(tgt)
                        if attr in lazy:
                            assigned.add(attr)
        if not assigned:
            continue
        guarded = False
        for anc in ctx.ancestors(node):
            if isinstance(anc, _FN_SCOPES):
                break
            if isinstance(anc, ast.With):
                for item in anc.items:
                    name = _terminal_name(item.context_expr) or ""
                    if isinstance(item.context_expr, ast.Call):
                        name = _terminal_name(item.context_expr.func) or ""
                    if name in ctx.lock_names or _LOCKISH_RE.search(name):
                        guarded = True
        if not guarded:
            attrs = ", ".join(sorted(assigned))
            out.append(_find(
                ctx, "guarded-lazy-init", node,
                f"lazy init of self.{attrs} under 'is None' is not inside "
                "a 'with <lock>:' block; racing threads each build (and "
                "one leaks) the resource"))
    return out


def _names_in(node: ast.AST) -> Iterable[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr


def _has_stop_path(fn: ast.AST) -> bool:
    """Heuristic: the thread's loop consults a stop flag / Event, or
    bails on a sentinel (`if x is None: return/break`)."""
    for name in _names_in(fn):
        if _STOPPISH_RE.search(name):
            return True
    for sub in ast.walk(fn):
        if isinstance(sub, ast.If):
            sentinel = any(
                isinstance(c, ast.Compare) and isinstance(c.ops[0], ast.Is)
                and isinstance(c.comparators[0], ast.Constant)
                and c.comparators[0].value is None
                for c in ast.walk(sub.test) if isinstance(c, ast.Compare))
            if sentinel and any(isinstance(s, (ast.Return, ast.Break))
                                for st in sub.body for s in ast.walk(st)):
                return True
    return False


def _resolve_target_fn(ctx: FileCtx, call: ast.Call) -> Optional[ast.AST]:
    tgt = next((kw.value for kw in call.keywords if kw.arg == "target"),
               None)
    if not isinstance(tgt, ast.Name):
        return None
    scopes = [a for a in ctx.ancestors(call)
              if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))]
    scopes.append(ctx.tree)
    for scope in scopes:
        body = scope.body if hasattr(scope, "body") else []
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == tgt.id:
                return stmt
    return None


def pass_thread_lifecycle(ctx: FileCtx) -> List[Finding]:
    """A daemon thread with no stop/sentinel/join path runs until the
    interpreter dies -- holding sockets, queues and locks its owner
    thinks are released (the PR-5 leaked-replication-thread bug class)."""
    out = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and _is_threading_ctor(node, ("Thread",))):
            continue
        daemon_kw = next(
            (kw for kw in node.keywords if kw.arg == "daemon"), None)
        if daemon_kw is None or not (
                isinstance(daemon_kw.value, ast.Constant)
                and daemon_kw.value.value is True):
            continue
        cls = ctx.enclosing(node, ast.ClassDef)
        if cls is not None:
            has_stop_method = any(
                isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                and re.match(r"(stop|close|shutdown|terminate|__exit__)",
                             stmt.name)
                for stmt in cls.body)
            has_join = any(
                isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "join"
                for sub in ast.walk(cls))
            if has_stop_method or has_join:
                continue
            out.append(_find(
                ctx, "thread-lifecycle", node,
                f"daemon Thread in class {cls.name} has no reachable "
                "stop path: no stop/close/shutdown/__exit__ method and "
                "no join() anywhere in the class"))
            continue
        target_fn = _resolve_target_fn(ctx, node)
        if target_fn is not None and _has_stop_path(target_fn):
            continue
        out.append(_find(
            ctx, "thread-lifecycle", node,
            "daemon Thread outside a class: its target must consult a "
            "stop flag/Event or exit on a sentinel (None) item"))
    return out


def pass_monotonic_deadlines(ctx: FileCtx) -> List[Finding]:
    """Lease expiry, straggler detection and wait deadlines must come
    from a monotonic clock; time.time() jumps with NTP steps and DST,
    silently expiring (or immortalizing) leases."""
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Attribute):
            continue
        base = node.value
        if isinstance(base, ast.Name) and base.id == "time" \
                and node.attr == "time":
            out.append(_find(
                ctx, "monotonic-deadlines", node,
                "wall-clock time.time() in fabric code; use "
                "repro.utils.timing.now() (time.perf_counter, monotonic)"))
        if node.attr in ("now", "utcnow") and \
                _terminal_name(base) == "datetime":
            out.append(_find(
                ctx, "monotonic-deadlines", node,
                "wall-clock datetime in fabric code; use "
                "repro.utils.timing.now() (monotonic) for deadlines"))
    return out


_HEADER_SINKS = {"request", "_send", "send_frame"}
_BLOB_MAKERS = {"dumps", "serialize", "dump"}


def pass_frame_header_hygiene(ctx: FileCtx) -> List[Finding]:
    """Wire headers are small plain dicts (string keys, primitive
    values) pickled once per hop; the envelope payload rides the frame
    body as opaque bytes.  Embedding serialized blobs in a header -- or
    unpickling payload bytes in relay code -- silently breaks the
    single-pickle-per-hop contract the fabric's overhead numbers and
    isolation rest on."""
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _terminal_name(node.func)
        if fname not in _HEADER_SINKS:
            continue
        exprs = [a for a in node.args] + [kw.value for kw in node.keywords]
        for arg in exprs:
            if not isinstance(arg, ast.Dict):
                continue
            if not any(isinstance(k, ast.Constant) and k.value == "op"
                       for k in arg.keys):
                continue                # not a wire header
            for k in arg.keys:
                if k is None or not (isinstance(k, ast.Constant)
                                     and isinstance(k.value, str)):
                    out.append(Finding(
                        "frame-header-hygiene", ctx.rel,
                        (k or arg).lineno,
                        "wire header keys must be string literals "
                        "(plain dict of primitives)"))
            for v in arg.values:
                for sub in ast.walk(v):
                    bad = (isinstance(sub, ast.Call)
                           and _terminal_name(sub.func) in _BLOB_MAKERS) \
                        or isinstance(sub, ast.Lambda)
                    if bad:
                        out.append(Finding(
                            "frame-header-hygiene", ctx.rel, sub.lineno,
                            "serialized blob embedded in a wire header; "
                            "payload bytes ride the frame body, headers "
                            "stay primitive"))
    # the shm descriptor is a header field like any other: the value
    # stored under "shm" (frame header) or "_shm" (envelope meta) must
    # stay the flat {"name", "size"} dict create_segment hands back --
    # a serialized blob there would smuggle the payload back into the
    # header the lane exists to keep it out of
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Subscript)
                and isinstance(tgt.slice, ast.Constant)
                and tgt.slice.value in ("shm", "_shm")):
            continue
        for sub in ast.walk(node.value):
            if (isinstance(sub, ast.Call)
                    and _terminal_name(sub.func) in _BLOB_MAKERS) \
                    or isinstance(sub, ast.Lambda):
                out.append(Finding(
                    "frame-header-hygiene", ctx.rel, sub.lineno,
                    "shm descriptor must stay a flat dict of primitives "
                    "(create_segment's {name, size}); payload bytes "
                    "belong in the segment, not its descriptor"))
    if ctx.rel.replace("\\", "/").endswith(RELAY_MODULES):
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("loads", "dumps")
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "pickle"):
                continue
            touches_payload = any(
                (isinstance(sub, ast.Name)
                 and sub.id in ("payload", "blob", "data"))
                or (isinstance(sub, ast.Attribute) and sub.attr == "data")
                for a in node.args for sub in ast.walk(a))
            if touches_payload:
                out.append(_find(
                    ctx, "frame-header-hygiene", node,
                    "relay code re-pickles envelope payload bytes; "
                    "envelopes are relayed verbatim "
                    "(single-pickle-per-hop)"))
    return out


# modules that OWN shm segments (may unlink; their reads cannot race an
# unlink because destruction is their own, locked decision).  Everyone
# else is a producer (creates, hands off, never unlinks post-handoff)
# or a consumer (maps and reads, never unlinks).
_SHM_OWNER_MODULES = ("transport/broker.py",)


def _catches_oserror(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True                     # bare except covers OSError
    names = {_terminal_name(sub) for sub in ast.walk(t)}
    return bool(names & {"OSError", "IOError", "FileNotFoundError",
                         "Exception", "BaseException"})


def pass_shm_segment_lifecycle(ctx: FileCtx) -> List[Finding]:
    """The shared-memory lane's ownership protocol (see transport/shm.py):
    producers create and hand off, the broker owns from receipt to
    envelope destruction, consumers only map and read.  This pass checks
    the call-site side of that contract -- a creator without an inline
    fallback turns an optimization into a correctness dependency, a
    consumer that unlinks destroys a segment the broker may redeliver,
    and an unguarded consumer read crashes on the benign expired-lease
    race instead of dropping the raced copy."""
    rel = ctx.rel.replace("\\", "/")
    if rel.endswith("transport/shm.py"):
        return []                       # the primitives themselves
    owner = rel.endswith(_SHM_OWNER_MODULES)
    out = []
    create_calls = []
    calls_sweep = False
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _terminal_name(node.func)
        if fname == "sweep_scope":
            calls_sweep = True
        elif fname == "create_segment":
            create_calls.append(node)
            guarded = any(
                isinstance(anc, ast.Try)
                and any(_catches_oserror(h) for h in anc.handlers)
                for anc in ctx.ancestors(node))
            if not guarded:
                out.append(_find(
                    ctx, "shm-segment-lifecycle", node,
                    "create_segment without an OSError fallback: the shm "
                    "lane is an optimization -- a full or missing "
                    "namespace must fall back to inline payloads, not "
                    "fail the send"))
        elif fname == "read_segment" and not owner:
            guarded = any(
                isinstance(anc, ast.Try)
                and any(_catches_oserror(h) for h in anc.handlers)
                for anc in ctx.ancestors(node))
            if not guarded:
                out.append(_find(
                    ctx, "shm-segment-lifecycle", node,
                    "consumer read_segment without an OSError guard: an "
                    "expired lease's other copy may be acked (segment "
                    "destroyed) under this reader -- drop the raced "
                    "copy, don't crash the consumer"))
        elif fname == "unlink_segment" and not owner:
            out.append(_find(
                ctx, "shm-segment-lifecycle", node,
                "unlink_segment outside the broker: segment ownership "
                "transfers with the frame -- a producer-side unlink "
                "after an ambiguous send destroys a delivered "
                "envelope's payload; leaks are reclaimed by the scope "
                "sweep instead"))
    if create_calls and not calls_sweep:
        out.append(_find(
            ctx, "shm-segment-lifecycle", create_calls[0],
            "module creates segments but never sweeps its scope: a "
            "producer that dies between create and handoff leaks the "
            "segment until sweep_scope runs at fabric teardown"))
    return out


# obs.<method> -> (index of the name argument, registry, registry label)
_OBS_NAME_SITES = {
    "span": (1, SPAN_NAMES, "SPAN_NAMES"),
    "instant": (1, SPAN_NAMES, "SPAN_NAMES"),
    "counter": (0, METRIC_NAMES, "METRIC_NAMES"),
    "gauge": (0, METRIC_NAMES, "METRIC_NAMES"),
    "histo": (0, METRIC_NAMES, "METRIC_NAMES"),
    "observe": (0, METRIC_NAMES, "METRIC_NAMES"),
}


def pass_span_name_registry(ctx: FileCtx) -> List[Finding]:
    """Span and metric names are the join keys of the whole
    observability plane: the report merges per-process sinks by name,
    and the Fig.-5 decomposition maps span names onto Timer components.
    A typo'd or dynamically built name doesn't error -- it just
    fragments the timeline into series nobody aggregates.  Every
    ``obs.span``/``obs.instant``/``obs.counter``/``obs.gauge``/
    ``obs.histo``/``obs.observe`` call site (the ``from repro import
    observability as obs`` convention) must therefore name a literal
    declared in ``repro.observability.names``."""
    out = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "obs"
                and node.func.attr in _OBS_NAME_SITES):
            continue
        idx, registry, label = _OBS_NAME_SITES[node.func.attr]
        name_arg = node.args[idx] if len(node.args) > idx else next(
            (kw.value for kw in node.keywords if kw.arg == "name"), None)
        if name_arg is None:
            continue                    # malformed call: TypeError at runtime
        if not (isinstance(name_arg, ast.Constant)
                and isinstance(name_arg.value, str)):
            out.append(_find(
                ctx, "span-name-registry", node,
                f"obs.{node.func.attr}() with a non-literal name: "
                "dynamic names fragment the merged timeline; use a "
                "literal declared in repro/observability/names.py"))
        elif name_arg.value not in registry:
            out.append(_find(
                ctx, "span-name-registry", node,
                f"obs.{node.func.attr}({name_arg.value!r}) names an "
                f"undeclared {node.func.attr}; add it to {label} in "
                "repro/observability/names.py (one-line description) "
                "so the report and rollups aggregate it"))
    return out


PASSES: Dict[str, Callable[[FileCtx], List[Finding]]] = {
    "wait-needs-predicate": pass_wait_needs_predicate,
    "idempotent-retry-registry": pass_idempotent_retry_registry,
    "guarded-lazy-init": pass_guarded_lazy_init,
    "thread-lifecycle": pass_thread_lifecycle,
    "monotonic-deadlines": pass_monotonic_deadlines,
    "frame-header-hygiene": pass_frame_header_hygiene,
    "shm-segment-lifecycle": pass_shm_segment_lifecycle,
    "span-name-registry": pass_span_name_registry,
}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    files = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(
                f for f in p.rglob("*.py") if "__pycache__" not in f.parts))
        else:
            files.append(p)
    return files


def run(paths: Sequence[Path],
        passes: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the named passes (default: all) over ``paths``; suppression
    pragmas are honored here so callers see only live findings."""
    selected = {n: PASSES[n] for n in (passes or PASSES)}
    findings: List[Finding] = []
    for path in iter_py_files([Path(p) for p in paths]):
        try:
            rel = str(path.resolve().relative_to(REPO_ROOT))
        except ValueError:
            rel = str(path)
        ctx = FileCtx(path, rel, path.read_text())
        for name, fn in selected.items():
            findings.extend(
                f for f in fn(ctx) if not ctx.suppressed(name, f.line))
    findings.sort(key=lambda f: (f.file, f.line, f.pass_name))
    return findings


def load_baseline(path: Path) -> List[dict]:
    if not path.exists():
        return []
    return json.loads(path.read_text()).get("findings", [])


def save_baseline(path: Path, findings: Sequence[Finding]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(
        {"comment": "fabriclint ratchet: entries here are grandfathered; "
                    "new findings fail --check.  Shrink, never grow.",
         "findings": [f.__dict__ for f in findings]},
        indent=2, sort_keys=True) + "\n")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.fabriclint",
        description="concurrency-invariant analyzer for the dispatch fabric")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to analyze (default: src/repro/core)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on findings not in the baseline (default "
                         "behavior; flag kept for explicit CI invocation)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current finding set")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline path (default: analysis/baseline.json "
                         "when analyzing the default target, none for "
                         "explicit paths)")
    ap.add_argument("--pass", dest="only_passes", action="append",
                    metavar="NAME", choices=sorted(PASSES),
                    help="run only this pass (repeatable)")
    args = ap.parse_args(argv)

    paths = args.paths or list(DEFAULT_TARGETS)
    baseline_path = args.baseline
    if baseline_path is None and not args.paths:
        baseline_path = DEFAULT_BASELINE

    findings = run(paths, args.only_passes)

    if args.update_baseline:
        save_baseline(baseline_path or DEFAULT_BASELINE, findings)
        print(f"baseline updated: {len(findings)} finding(s)")
        return 0

    baseline_keys = {(b["pass_name"], b["file"], b["message"])
                     for b in load_baseline(baseline_path)} \
        if baseline_path else set()
    new = [f for f in findings if f.key() not in baseline_keys]
    old = [f for f in findings if f.key() in baseline_keys]

    for f in new:
        print(f.render())
    if old:
        print(f"note: {len(old)} baselined finding(s) remain "
              "(see analysis/baseline.json)")
    stale = baseline_keys - {f.key() for f in findings}
    if stale:
        print(f"note: {len(stale)} baseline entr(ies) no longer fire; "
              "run --update-baseline to ratchet down")
    if new:
        print(f"fabriclint: {len(new)} new finding(s)")
        return 1
    print(f"fabriclint: clean ({len(findings)} total, "
          f"{len(old)} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
