"""Static + runtime checking of the dispatch fabric's concurrency invariants.

Two halves:

- ``fabriclint`` -- an AST analyzer over ``src/repro/core/**`` and
  ``src/repro/serving/**`` whose named
  passes encode the invariants the fabric's correctness rests on
  (predicate loops around ``Condition.wait``, the idempotent-op registry
  behind reconnect-resend, lock-guarded lazy init, daemon-thread
  lifecycle, monotonic deadlines, single-pickle-per-hop frame hygiene).
  Run as ``python -m repro.analysis.fabriclint --check``.

- ``witness`` -- an opt-in runtime lock-order witness: instrumented
  Lock/RLock/Condition wrappers that record each thread's acquisition
  chain, build the global acquisition graph, and fail fast on a cycle.
  The known-good edge set is checked in at ``analysis/lock_order.toml``;
  the pytest ``--lock-witness`` option (see ``tests/conftest.py``)
  activates it for a whole test run.
"""
