"""The idempotent-op registry behind reconnect-resend (``retry=True``).

``FrameClient.request(..., retry=True)`` reconnects and resends a frame
whose connection died mid-exchange.  That is only sound for ops whose
resend cannot change server state or mis-answer the caller -- the op may
already have been applied before the connection died.  Every op named at
a ``retry=True`` call site must appear here with a one-line justification;
the ``idempotent-retry-registry`` fabriclint pass enforces it, replacing
the ad-hoc ``# retry=True is safe: ...`` comments that previously carried
this argument at each site.

Deliberately ABSENT (their call sites must not pass ``retry=True``):

- ``put`` / ``vs_put`` / ``vs_release`` / ``claim`` -- may have been
  applied before the drop; a resend double-applies or answers the
  rightful first claimant False.
- ``get`` -- a leased dequeue.  A dropped response merely strands a
  lease that expires and redelivers; a resend would fetch *different*
  envelopes under a second lease and hide the failure.
- ``renew`` / ``ack`` -- a lost renew is healed by the next heartbeat
  tick; acks are restored to the pending set and ride the next frame.
- ``backup`` -- a resend of an applied straggler clone enqueues a
  *second* clone; harmless (claim dedup) but wasteful, and the
  straggler timer re-fires on its own if the first send truly died.
- ``cancel`` -- fused claim: an applied-then-dropped cancel's resend
  would answer ``won=False`` to the rightful first canceller, who
  would then skip its own bookkeeping for a cancel that *did* land.
- ``put_stream`` -- an observation publish; a resend could
  double-publish the observation under the same seq.  Observations are
  advisory (no claim, no lease), so losing one to a dropped connection
  is cheaper than duplicating it.
"""

IDEMPOTENT_OPS = {
    # broker ops (transport/proc.py, cluster/federation.py)
    "len": "read-only queue-depth probe; a resend cannot change state",
    "wake": "epochs only ever bump; waking twice == waking once",
    "snapshot": "read-only serialization of broker state",
    "restore": "wholesale state replacement; the same snapshot twice "
               "converges to the same state",
    "endpoints": "read-only topology advertisement (peer map, partition, "
                 "machine, shm scope)",
    # value-server shard ops (transport/shards.py, cluster/launcher.py)
    "vs_ring": "read-only fetch of the current ring message",
    "vs_set_ring": "epoch-guarded install; shards keep the max epoch, so "
                   "a resend of an applied ring is a no-op",
    "vs_get": "read-only payload fetch",
    "vs_size_of": "read-only size probe",
    "vs_contains": "read-only membership probe",
    "vs_delete": "deleting an absent key is a no-op; a resend of an "
                 "applied delete converges",
    "vs_keys": "read-only key inventory",
    "vs_export": "read-only dump of one key's stored bytes + refcount",
    "vs_expect": "epoch-guarded set union of incoming-key announcements; "
                 "a resend converges to the same window",
    "vs_end_expect": "epoch-guarded clear of the expect window; clearing "
                     "twice == clearing once",
    "vs_snapshot": "read-only serialization of one shard's contents",
    "vs_stats": "read-only counter probe",
    "cancelled": "read-only membership probe of the bounded cancelled-id "
                 "window; a resend cannot change state",
    # observability ops (transport/broker.py; see repro/observability)
    "clock_sync": "read-only monotonic-clock probe; the caller keeps only "
                  "the min-RTT round, so a resend merely adds a sample",
    "stats_scrape": "read-only queue-depth/lease/metrics snapshot "
                    "(lease expiry it piggybacks is itself idempotent)",
}
