"""Runtime lock-order witness for the dispatch fabric.

Lockdep for the fabric: instrumented ``Lock``/``RLock``/``Condition``
wrappers record, per thread, which locks are held when another is
acquired.  Each (held -> acquired) pair is an edge in the global
acquisition graph; a cycle in that graph is a potential deadlock even
if this run never interleaved into it, so the witness fails **on the
acquisition attempt that would close the cycle** -- before the program
can actually deadlock and hang the test run.

The known-good edge set is checked in at ``analysis/lock_order.toml``
(e.g. the broker's documented claim_lock -> queue-cond order).  A new
edge is not an error by itself -- it fails the pytest session as an
*undeclared* ordering so the diff to ``lock_order.toml`` is explicit
and reviewed.  Acquiring two same-named locks (two instances from one
creation site, e.g. the snapshot cut's ExitStack over every queue
Condition) is a cycle-in-waiting unless that site is declared under
``[self_edges]`` with a justification.

Activation is opt-in: ``install()`` monkeypatches the ``threading``
factories so only locks *created* by ``src/repro`` code (decided by the
caller's frame) are wrapped; stdlib internals (Event, ThreadPoolExecutor,
multiprocessing) keep raw locks.  Forked children inherit the installed
witness object (sink path and all) along with the patched factories;
every edge is appended to the sink file (``O_APPEND``, one JSON
line) the moment it is first seen, so edges observed in a worker that
exits via ``os._exit`` (skipping atexit) are still collected.  The
pytest plugin in ``tests/conftest.py`` wires this up under
``--lock-witness``.
"""
from __future__ import annotations

import json
import os
import re
import sys
import threading
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition


class LockOrderError(RuntimeError):
    """An acquisition that would close a cycle in the lock-order graph."""


# ---------------------------------------------------------------------------
# the witness core
# ---------------------------------------------------------------------------


class Witness:
    def __init__(self, sink: Optional[str] = None,
                 allowed_self_edges: Iterable[str] = ()):
        self._tls = threading.local()
        self._mu = _REAL_LOCK()          # guards graph/edges (never wrapped)
        self._graph: Dict[str, Set[str]] = {}
        self.edges: Dict[Tuple[str, str], str] = {}   # edge -> first site
        self.self_edges: Dict[str, str] = {}          # name -> first site
        self.allowed_self_edges = set(allowed_self_edges)
        self.sink = sink
        self.active = True

    # -- held-stack plumbing (thread-local, no locking needed) --------------

    def _held(self) -> List[Tuple[str, int]]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    # -- graph ---------------------------------------------------------------

    def _path_exists(self, src: str, dst: str) -> bool:
        seen, stack = set(), [src]
        while stack:
            cur = stack.pop()
            if cur == dst:
                return True
            if cur in seen:
                continue
            seen.add(cur)
            stack.extend(self._graph.get(cur, ()))
        return False

    def _emit(self, record: dict) -> None:
        # only this witness's own sink: a throwaway Witness in a test
        # must never leak its seeded edges into a session-wide sink.
        # Forked children inherit the installed witness object itself,
        # sink and all -- no environment relay needed.
        sink = self.sink
        if not sink:
            return
        line = (json.dumps(record, sort_keys=True) + "\n").encode()
        fd = os.open(sink, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, line)          # O_APPEND: atomic for short lines
        finally:
            os.close(fd)

    def _site(self) -> str:
        f = sys._getframe(2)
        while f is not None and (
                f.f_code.co_filename == __file__
                or f.f_code.co_filename == threading.__file__):
            f = f.f_back
        if f is None:
            return "?"
        return f"{os.path.basename(f.f_code.co_filename)}:{f.f_lineno}"

    # -- acquisition hooks ---------------------------------------------------

    def before_acquire(self, name: str, ident: int) -> None:
        """Called before the real acquire blocks: record (held ->
        wanted) edges and fail if one would close a cycle."""
        if not self.active:
            return
        held = self._held()
        if not held:
            return
        if any(hid == ident for _, hid in held):
            return                      # reentrant acquire of an RLock
        site = self._site()
        for hname, hid in held:
            if hname == name:
                # second instance from the same creation site
                if name in self.allowed_self_edges:
                    with self._mu:
                        if name not in self.self_edges:
                            self.self_edges[name] = site
                            self._emit({"self_edge": name, "site": site})
                    continue
                raise LockOrderError(
                    f"two locks from the same creation site {name!r} held "
                    f"at once (at {site}); order between instances is "
                    "undefined -- declare the site under [self_edges] in "
                    "analysis/lock_order.toml with a justification, or "
                    "impose a total order")
            edge = (hname, name)
            if edge in self.edges:
                continue
            with self._mu:
                if edge in self.edges:
                    continue
                if self._path_exists(name, hname):
                    cycle = f"{hname} -> {name} -> ... -> {hname}"
                    raise LockOrderError(
                        f"lock-order cycle: acquiring {name!r} while "
                        f"holding {hname!r} (at {site}) closes {cycle}; "
                        "the reverse order is already on record")
                self.edges[edge] = site
                self._graph.setdefault(hname, set()).add(name)
            self._emit({"edge": [hname, name], "site": site})

    def on_acquired(self, name: str, ident: int) -> None:
        if self.active:
            self._held().append((name, ident))

    def on_release(self, name: str, ident: int) -> None:
        if not self.active:
            return
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == (name, ident):
                del held[i]
                return


# ---------------------------------------------------------------------------
# instrumented primitives
# ---------------------------------------------------------------------------


class WitnessLock:
    """Duck-typed Lock/RLock wrapper.  Provides the private Condition
    protocol (``_is_owned``/``_release_save``/``_acquire_restore``) by
    delegating to the inner lock, so a real ``threading.Condition`` built
    over a WitnessLock works unchanged -- ``wait()``'s internal
    release/reacquire bypasses the witness (the thread is blocked, its
    held-stack is frozen, and the stack stays consistent either side of
    the wait)."""

    def __init__(self, witness: Witness, name: str, inner=None):
        self._witness = witness
        self._name = name
        self._inner = inner if inner is not None else _REAL_LOCK()

    def acquire(self, blocking: bool = True, timeout: float = -1):
        self._witness.before_acquire(self._name, id(self))
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._witness.on_acquired(self._name, id(self))
        return got

    def release(self):
        self._inner.release()
        self._witness.on_release(self._name, id(self))

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()

    def __repr__(self):
        return f"<WitnessLock {self._name} over {self._inner!r}>"

    # -- Condition protocol --------------------------------------------------

    def _is_owned(self):
        inner = self._inner
        if hasattr(inner, "_is_owned"):
            return inner._is_owned()
        if inner.acquire(False):
            inner.release()
            return False
        return True

    def _release_save(self):
        inner = self._inner
        if hasattr(inner, "_release_save"):
            return inner._release_save()
        inner.release()
        return None

    def _acquire_restore(self, state):
        inner = self._inner
        if hasattr(inner, "_acquire_restore"):
            inner._acquire_restore(state)
        else:
            inner.acquire()


# ---------------------------------------------------------------------------
# creation-site naming + threading patch
# ---------------------------------------------------------------------------

_ASSIGN_RE = re.compile(
    r"([\w.\[\]'\"]+)\s*=\s*(?:threading\s*\.\s*)?(?:Lock|RLock|Condition)\(")


def _creation_name(depth: int = 2) -> Tuple[str, bool]:
    """(name, in_repro): name a lock by its creation site -- the
    assignment target when the source line is an assignment
    (``core/transport/broker.py:self._claim_lock``), file:line
    otherwise.  Two instances born at one site share a name: that is
    what makes the graph finite and same-site multi-instance holds
    detectable."""
    import linecache
    f = sys._getframe(depth)
    fname = f.f_code.co_filename
    norm = fname.replace("\\", "/")
    in_repro = "/repro/" in norm and "/analysis/" not in norm
    if "/repro/" in norm:
        short = norm.rsplit("/repro/", 1)[1]
    else:
        short = os.path.basename(norm)
    line = linecache.getline(fname, f.f_lineno)
    # C-extension code (numpy's Cython BitGenerator, etc.) creates locks
    # with no Python frame of its own -- the nearest frame is whatever
    # repro line *called* it.  Only claim the lock when the source line
    # itself invokes the constructor.
    if not re.search(r"\b(Lock|RLock|Condition)\s*\(", line):
        return f"{short}:L{f.f_lineno}", False
    m = _ASSIGN_RE.search(line)
    target = m.group(1) if m else f"L{f.f_lineno}"
    return f"{short}:{target}", in_repro


_installed: Optional[Witness] = None


def install(witness: Witness) -> Witness:
    """Patch the ``threading`` lock factories.  Only locks created by
    ``src/repro`` code (the calling frame) are wrapped; everything else
    gets the real primitive.  Idempotent per process; ``uninstall()``
    restores the originals (already-wrapped locks keep functioning)."""
    global _installed
    if _installed is not None:
        raise RuntimeError("witness already installed")
    _installed = witness

    def _lock():
        name, in_repro = _creation_name()
        if not in_repro:
            return _REAL_LOCK()
        return WitnessLock(witness, name, _REAL_LOCK())

    def _rlock():
        name, in_repro = _creation_name()
        if not in_repro:
            return _REAL_RLOCK()
        return WitnessLock(witness, name, _REAL_RLOCK())

    def _condition(lock=None):
        name, in_repro = _creation_name()
        if not in_repro:
            return _REAL_CONDITION(lock)
        if lock is None:
            # private RLock, named by the condition's creation site
            lock = WitnessLock(witness, name, _REAL_RLOCK())
        elif not isinstance(lock, WitnessLock):
            lock = WitnessLock(witness, name, lock)
        # a real Condition over the witness lock: enter/exit/notify go
        # through the witness, wait()'s release/reacquire bypasses it
        return _REAL_CONDITION(lock)

    threading.Lock = _lock
    threading.RLock = _rlock
    threading.Condition = _condition
    return witness


def uninstall() -> Optional[Witness]:
    global _installed
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    threading.Condition = _REAL_CONDITION
    w, _installed = _installed, None
    if w is not None:
        w.active = False
    return w


def installed() -> Optional[Witness]:
    return _installed


# ---------------------------------------------------------------------------
# known-good order file (analysis/lock_order.toml)
# ---------------------------------------------------------------------------


def _parse_string_arrays(text: str) -> Dict[str, List[str]]:
    """Tiny TOML-subset reader (Python 3.10 has no tomllib): sections,
    ``key = [`` multi-line arrays of double-quoted strings, comments."""
    out: Dict[str, List[str]] = {}
    section = ""
    key = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[") and line.endswith("]"):
            section = line[1:-1].strip()
            key = None
            continue
        m = re.match(r"(\w+)\s*=\s*\[", line)
        if m:
            key = f"{section}.{m.group(1)}"
            out[key] = []
            line = line[m.end():]
        if key is None:
            continue
        for s in re.findall(r'"([^"]*)"', line):
            out[key].append(s)
        if line.split("#", 1)[0].rstrip().endswith("]"):
            key = None
    return out


def load_lock_order(path) -> Tuple[Set[Tuple[str, str]], Set[str]]:
    """Returns (known edges, allowed self-edge sites)."""
    text = Path(path).read_text()
    try:
        import tomllib
        data = tomllib.loads(text)
        pairs = data.get("edges", {}).get("pairs", [])
        selfs = data.get("self_edges", {}).get("allowed", [])
    except ModuleNotFoundError:
        arrays = _parse_string_arrays(text)
        pairs = arrays.get("edges.pairs", [])
        selfs = arrays.get("self_edges.allowed", [])
    edges = set()
    for p in pairs:
        a, _, b = p.partition(" -> ")
        if not b:
            raise ValueError(f"malformed edge {p!r} (want 'a -> b')")
        edges.add((a.strip(), b.strip()))
    return edges, set(s.strip() for s in selfs)


def read_sink(path) -> Tuple[Dict[Tuple[str, str], str], Dict[str, str]]:
    """Merge a sink file (possibly written by several processes) back
    into (edges, self_edges)."""
    edges: Dict[Tuple[str, str], str] = {}
    selfs: Dict[str, str] = {}
    p = Path(path)
    if not p.exists():
        return edges, selfs
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        if "edge" in rec:
            edges.setdefault(tuple(rec["edge"]), rec.get("site", "?"))
        elif "self_edge" in rec:
            selfs.setdefault(rec["self_edge"], rec.get("site", "?"))
    return edges, selfs
