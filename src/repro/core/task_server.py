"""Task Server: high-throughput dispatch of Thinker requests to workers.

The paper implements this with Parsl over ZeroMQ; here Workers are thread
pools (one pool per task topic, sized by the ResourceTracker allocation)
executing registered Python methods -- which on the TPU adaptation are
jit-compiled mesh programs (warm-compile caches play the role of the
paper's "warmed" Python workers).  For true process parallelism (the
paper's worker topology) see ``repro.core.process_pool.
ProcessPoolTaskServer``, which runs the same registered methods in worker
OS processes over the ``proc`` queue backend and adds per-worker identity
for backup placement; this thread server remains the low-overhead choice
when tasks release the GIL or run on-device.

Dispatch is event-driven: intake threads block on the queue's Condition
and drain batches per wakeup (no 50 ms polling), and the straggler monitor
sleeps until the earliest in-flight duplicate-dispatch *deadline* (or a
new-work notification) rather than spinning on a fixed interval.

Fault tolerance (1000+ node posture):
- per-task retry with capped attempts; errors are captured into the Result
  (never lost),
- straggler mitigation: tasks exceeding `straggler_factor` x the topic's
  trailing-median runtime are duplicated onto a backup worker; first
  completion wins (duplicate results are dropped via a *bounded* dedup
  window -- only ids involved in a backup race are recorded, capped at
  `dedup_window` entries, so long campaigns don't leak memory),
- worker crash simulation hooks for tests (inject_failure).
"""
from __future__ import annotations

import threading
import traceback
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Optional

from repro import observability as obs
from repro.core import message as msg
from repro.core import streaming
from repro.core.queues import ColmenaQueues
from repro.core.transport.base import BoundedIdSet as _BoundedIdSet
from repro.core.value_server import resolve_tree
from repro.utils.timing import now


class MethodSpec:
    def __init__(self, fn: Callable, *, topic: str, max_retries: int = 1,
                 slots_per_task: int = 1, pool: Optional[str] = None):
        self.fn = fn
        self.topic = topic
        self.max_retries = max_retries
        self.slots_per_task = slots_per_task
        self.pool = pool or topic


class TaskServer:
    def __init__(self, queues: ColmenaQueues, *, workers_per_topic: int = 4,
                 resources=None, straggler_factor: Optional[float] = None,
                 straggler_min_history: int = 5, dedup_window: int = 4096,
                 intake_batch: int = 32):
        self.queues = queues
        self.resources = resources
        self.straggler_factor = straggler_factor
        self.straggler_min_history = straggler_min_history
        self.intake_batch = intake_batch
        self._methods: Dict[str, MethodSpec] = {}
        self._pools: Dict[str, ThreadPoolExecutor] = {}
        self._workers_per_topic = workers_per_topic
        self._caches: Dict[str, dict] = {}       # per-topic proxy caches
        self._stop = threading.Event()
        self._threads: list = []
        self._runtimes: Dict[str, list] = {}     # topic -> recent runtimes
        self._inflight: Dict[str, dict] = {}     # task_id -> info
        # bounded dedup: only ids involved in a backup race are recorded
        self._raced_ids = _BoundedIdSet(dedup_window)
        self._done_ids = _BoundedIdSet(dedup_window)
        self._lock = threading.Lock()
        # signalled on: task started, task finished, history update, stop
        self._straggler_cond = threading.Condition(self._lock)

    # -- registration ---------------------------------------------------------

    def register(self, fn: Callable, *, topic: Optional[str] = None,
                 name: Optional[str] = None, max_retries: int = 1,
                 slots_per_task: int = 1, pool: Optional[str] = None):
        name = name or fn.__name__
        topic = topic or name
        self._methods[name] = MethodSpec(fn, topic=topic,
                                         max_retries=max_retries,
                                         slots_per_task=slots_per_task,
                                         pool=pool)
        return name

    # -- lifecycle --------------------------------------------------------------

    def start(self):
        topics = self.queues.topics()
        for t in topics:
            self._pools[t] = ThreadPoolExecutor(
                max_workers=self._workers_per_topic,
                thread_name_prefix=f"worker-{t}")
            self._caches[t] = {}
            th = threading.Thread(target=self._intake_loop, args=(t,),
                                  daemon=True, name=f"intake-{t}")
            th.start()
            self._threads.append(th)
        if self.straggler_factor:
            th = threading.Thread(target=self._straggler_loop, daemon=True,
                                  name="straggler-monitor")
            th.start()
            self._threads.append(th)
        return self

    def stop(self):
        self._stop.set()
        self.queues.wake_all()
        with self._lock:
            self._straggler_cond.notify_all()
        for th in self._threads:
            th.join(timeout=2)
        for p in self._pools.values():
            p.shutdown(wait=False, cancel_futures=True)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- internals ----------------------------------------------------------------

    def _intake_loop(self, topic: str):
        while not self._stop.is_set():
            tasks = self.queues.get_tasks(topic, max_n=self.intake_batch,
                                          cancel=self._stop)
            if not tasks:
                continue                    # woken for shutdown; loop checks
            with self._lock:
                for task in tasks:
                    self._inflight[task.task_id] = {
                        "task": task, "started": None, "backup_sent": False}
            for task in tasks:
                self._pools[topic].submit(self._run_task, task)

    def _lost_race_locked(self, task: msg.Task) -> bool:
        return ((task.is_backup or task.task_id in self._raced_ids)
                and task.task_id in self._done_ids)

    def _run_task(self, task: msg.Task):
        spec = self._methods[task.method]
        tid = threading.current_thread().name
        with self._lock:
            if self._lost_race_locked(task):
                return                      # backup lost the race pre-start
            info = self._inflight.get(task.task_id)
            if info is not None:
                info["started"] = now()
                self._straggler_cond.notify_all()
        cache = self._caches.get(task.topic, {})
        acquired = False
        try:
            if self.resources is not None:
                self.resources.acquire(spec.pool, spec.slots_per_task)
                acquired = True
            # async proxy resolution overlaps with worker start-up
            args = resolve_tree(task.args, self.queues.value_server, cache,
                                async_start=True)
            kwargs = resolve_tree(task.kwargs, self.queues.value_server,
                                  cache, async_start=True)
            args = resolve_tree(args, self.queues.value_server, cache)
            kwargs = resolve_tree(kwargs, self.queues.value_server, cache)
            if getattr(task, "trace", False):
                obs.instant(task.task_id, "task_started",
                            attempt=getattr(task, "attempt", 0), worker=tid)
            # streaming context: the user function's report_intermediate
            # publishes on the topic's stream lane and raises
            # TaskCancelled the moment the Thinker culls this task
            # (cooperative-only on the thread server -- no process to
            # signal)
            ctx = streaming.TaskContext(
                task.task_id, task.topic,
                stream=self.queues.stream_channel(task.topic),
                traced=bool(getattr(task, "trace", False)), worker=tid)
            streaming.set_context(ctx)
            t0 = now()
            try:
                value = spec.fn(*args, **kwargs)
            finally:
                streaming.clear_context()
            runtime = now() - t0
            task.timer.record("execute", runtime)
            if getattr(task, "trace", False):
                obs.span(task.task_id, "execute", t0, t0 + runtime,
                         attempt=getattr(task, "attempt", 0), worker=tid)
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=True, value=value, args=task.args,
                kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=tid)
            with self._lock:
                hist = self._runtimes.setdefault(task.topic, [])
                hist.append(runtime)
                del hist[:-50]
                self._straggler_cond.notify_all()
        except streaming.TaskCancelled:
            # preempted mid-execution: the cancel already claimed the id
            # and revoked broker state -- publish nothing, retry nothing
            # (routing this into the retry path would resubmit work the
            # Thinker explicitly culled)
            with self._lock:
                self._inflight.pop(task.task_id, None)
                self._straggler_cond.notify_all()
            return
        except Exception as e:                         # noqa: BLE001
            task.timer.record("execute", 0.0)
            with self._lock:
                lost = self._lost_race_locked(task)
            if lost:
                return                      # winner already delivered
            if task.retries < spec.max_retries:
                task.retries += 1
                with self._lock:
                    self._inflight.pop(task.task_id, None)
                if acquired and self.resources is not None:
                    self.resources.release(spec.pool, spec.slots_per_task)
                    acquired = False
                self.queues.requeue(task)
                return
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=False, error=f"{e!r}\n{traceback.format_exc()}",
                args=task.args, kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=tid)
        finally:
            if acquired and self.resources is not None:
                self.resources.release(spec.pool, spec.slots_per_task)

        with self._lock:
            raced = task.is_backup or task.task_id in self._raced_ids
            if raced:
                if task.task_id in self._done_ids:
                    return                  # duplicate (straggler backup)
                self._done_ids.add(task.task_id)
            self._inflight.pop(task.task_id, None)
            self._straggler_cond.notify_all()
        result.attempt = getattr(task, "attempt", 0)  # tags result spans
        self.queues.send_result(result)
        # only the race *winner* gets here (dedup), and a losing duplicate
        # that resolves afterwards fails into the lost-race drop path, so
        # releasing is safe even for straggler backups
        self.queues.release_task_inputs(task)

    def _straggler_loop(self):
        while True:
            fire = []
            with self._lock:
                if self._stop.is_set():
                    return
                tnow = now()
                next_deadline = None
                for _, info in self._inflight.items():
                    if info["started"] is None or info["backup_sent"]:
                        continue
                    task = info["task"]
                    hist = self._runtimes.get(task.topic, [])
                    if len(hist) < self.straggler_min_history:
                        continue
                    med = sorted(hist)[len(hist) // 2]
                    deadline = info["started"] + self.straggler_factor * med
                    if deadline <= tnow:
                        info["backup_sent"] = True
                        self._raced_ids.add(task.task_id)
                        fire.append(task)
                    elif next_deadline is None or deadline < next_deadline:
                        next_deadline = deadline
                if not fire:
                    # sleep until the earliest duplicate-dispatch deadline,
                    # or until new work starts / history changes / stop.
                    # now() is recomputed: tnow predates the O(inflight)
                    # scan, and waiting next_deadline - tnow would
                    # overshoot a deadline earned during it
                    if next_deadline is None:
                        self._straggler_cond.wait()
                    else:
                        self._straggler_cond.wait(max(next_deadline - now(),
                                                      0.0))
                    continue
            for task in fire:
                backup = msg.Task(topic=task.topic, method=task.method,
                                  args=task.args, kwargs=task.kwargs,
                                  task_id=task.task_id, is_backup=True)
                self._pools[task.topic].submit(self._run_task, backup)
