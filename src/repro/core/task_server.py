"""Task Server: high-throughput dispatch of Thinker requests to workers.

The paper implements this with Parsl over ZeroMQ; here Workers are thread
pools (one pool per task topic, sized by the ResourceTracker allocation)
executing registered Python methods -- which on the TPU adaptation are
jit-compiled mesh programs (warm-compile caches play the role of the
paper's "warmed" Python workers).

Fault tolerance (1000+ node posture):
- per-task retry with capped attempts; errors are captured into the Result
  (never lost),
- straggler mitigation: tasks exceeding `straggler_factor` x the topic's
  trailing-median runtime are duplicated onto a backup worker; first
  completion wins (duplicate results are marked and dropped by the queue
  layer's dedup),
- worker crash simulation hooks for tests (inject_failure).
"""
from __future__ import annotations

import threading
import traceback
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Optional

from repro.core import message as msg
from repro.core.queues import ColmenaQueues
from repro.core.value_server import resolve_tree
from repro.utils.timing import now


class MethodSpec:
    def __init__(self, fn: Callable, *, topic: str, max_retries: int = 1,
                 slots_per_task: int = 1, pool: Optional[str] = None):
        self.fn = fn
        self.topic = topic
        self.max_retries = max_retries
        self.slots_per_task = slots_per_task
        self.pool = pool or topic


class TaskServer:
    def __init__(self, queues: ColmenaQueues, *, workers_per_topic: int = 4,
                 resources=None, straggler_factor: Optional[float] = None,
                 straggler_min_history: int = 5):
        self.queues = queues
        self.resources = resources
        self.straggler_factor = straggler_factor
        self.straggler_min_history = straggler_min_history
        self._methods: Dict[str, MethodSpec] = {}
        self._pools: Dict[str, ThreadPoolExecutor] = {}
        self._workers_per_topic = workers_per_topic
        self._caches: Dict[str, dict] = {}       # per-topic proxy caches
        self._stop = threading.Event()
        self._threads: list = []
        self._runtimes: Dict[str, list] = {}     # topic -> recent runtimes
        self._inflight: Dict[str, dict] = {}     # task_id -> info
        self._done_ids: set = set()
        self._lock = threading.Lock()

    # -- registration ---------------------------------------------------------

    def register(self, fn: Callable, *, topic: Optional[str] = None,
                 name: Optional[str] = None, max_retries: int = 1,
                 slots_per_task: int = 1, pool: Optional[str] = None):
        name = name or fn.__name__
        topic = topic or name
        self._methods[name] = MethodSpec(fn, topic=topic,
                                         max_retries=max_retries,
                                         slots_per_task=slots_per_task,
                                         pool=pool)
        return name

    # -- lifecycle --------------------------------------------------------------

    def start(self):
        topics = self.queues.topics()
        for t in topics:
            self._pools[t] = ThreadPoolExecutor(
                max_workers=self._workers_per_topic,
                thread_name_prefix=f"worker-{t}")
            self._caches[t] = {}
            th = threading.Thread(target=self._intake_loop, args=(t,),
                                  daemon=True, name=f"intake-{t}")
            th.start()
            self._threads.append(th)
        if self.straggler_factor:
            th = threading.Thread(target=self._straggler_loop, daemon=True,
                                  name="straggler-monitor")
            th.start()
            self._threads.append(th)
        return self

    def stop(self):
        self._stop.set()
        for th in self._threads:
            th.join(timeout=2)
        for p in self._pools.values():
            p.shutdown(wait=False, cancel_futures=True)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- internals ----------------------------------------------------------------

    def _intake_loop(self, topic: str):
        while not self._stop.is_set():
            task = self.queues.get_task(topic, timeout=0.05)
            if task is None:
                continue
            with self._lock:
                self._inflight[task.task_id] = {
                    "task": task, "started": None, "backup_sent": False}
            self._pools[topic].submit(self._run_task, task)

    def _run_task(self, task: msg.Task):
        spec = self._methods[task.method]
        tid = threading.current_thread().name
        with self._lock:
            info = self._inflight.get(task.task_id)
            if info is not None:
                info["started"] = now()
            if task.task_id in self._done_ids:
                return                      # backup lost the race pre-start
        cache = self._caches.get(task.topic, {})
        acquired = False
        try:
            if self.resources is not None:
                self.resources.acquire(spec.pool, spec.slots_per_task)
                acquired = True
            # async proxy resolution overlaps with worker start-up
            args = resolve_tree(task.args, self.queues.value_server, cache,
                                async_start=True)
            kwargs = resolve_tree(task.kwargs, self.queues.value_server,
                                  cache, async_start=True)
            args = resolve_tree(args, self.queues.value_server, cache)
            kwargs = resolve_tree(kwargs, self.queues.value_server, cache)
            t0 = now()
            value = spec.fn(*args, **kwargs)
            runtime = now() - t0
            task.timer.record("execute", runtime)
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=True, value=value, args=task.args,
                kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=tid)
            with self._lock:
                hist = self._runtimes.setdefault(task.topic, [])
                hist.append(runtime)
                del hist[:-50]
        except Exception as e:                         # noqa: BLE001
            task.timer.record("execute", 0.0)
            if task.retries < spec.max_retries:
                task.retries += 1
                with self._lock:
                    self._inflight.pop(task.task_id, None)
                if acquired and self.resources is not None:
                    self.resources.release(spec.pool, spec.slots_per_task)
                self.queues.requeue(task)
                return
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=False, error=f"{e!r}\n{traceback.format_exc()}",
                args=task.args, kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=tid)
        finally:
            if acquired and self.resources is not None:
                self.resources.release(spec.pool, spec.slots_per_task)

        with self._lock:
            if task.task_id in self._done_ids:
                return                      # duplicate (straggler backup)
            self._done_ids.add(task.task_id)
            self._inflight.pop(task.task_id, None)
        self.queues.send_result(result)

    def _straggler_loop(self):
        import time
        while not self._stop.is_set():
            time.sleep(0.05)
            with self._lock:
                candidates = []
                for tid, info in self._inflight.items():
                    if info["started"] is None or info["backup_sent"]:
                        continue
                    task = info["task"]
                    hist = self._runtimes.get(task.topic, [])
                    if len(hist) < self.straggler_min_history:
                        continue
                    med = sorted(hist)[len(hist) // 2]
                    if now() - info["started"] > self.straggler_factor * med:
                        info["backup_sent"] = True
                        candidates.append(task)
            for task in candidates:
                backup = msg.Task(topic=task.topic, method=task.method,
                                  args=task.args, kwargs=task.kwargs,
                                  task_id=task.task_id, is_backup=True)
                self._pools[task.topic].submit(self._run_task, backup)
