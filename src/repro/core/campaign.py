"""Abstract campaign-steering formulation (paper §II-A).

Entities e in E with properties p in P; assays a in A estimate properties
(static assays = fixed simulation codes, learned assays = retrainable ML
models); the record D holds (entity, assay, property, value) observations;
a scoring function S maps an entity's data to a score (or None when the
data are inadequate); V(D) = best score in the record; C(D) = accumulated
cost.  The decision problem at each step: generate entities, run a task
a(e), or retrain a learned assay.

The CampaignRecord is JSON-serializable -- campaign state participates in
checkpoint/restart alongside model/optimizer state (fault tolerance).
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class Observation:
    entity: str                 # entity id
    assay: str                  # assay id
    prop: str                   # property name
    value: float
    cost: float = 0.0
    time: float = 0.0           # campaign wall-clock when recorded


@dataclass
class AssaySpec:
    name: str
    prop: str                   # property it estimates
    cost: float                 # nominal cost per application
    learned: bool = False       # retrainable?


class CampaignRecord:
    """Thread-safe record D with V(D) and C(D)."""

    def __init__(self, scoring_fn: Callable[[Dict[str, float]], Optional[float]]):
        self._lock = threading.Lock()
        self._obs: List[Observation] = []
        self._by_entity: Dict[str, Dict[str, float]] = {}
        self._scoring = scoring_fn

    def add(self, obs: Observation) -> None:
        with self._lock:
            self._obs.append(obs)
            self._by_entity.setdefault(obs.entity, {})[obs.prop] = obs.value

    def observations(self) -> List[Observation]:
        with self._lock:
            return list(self._obs)

    def entity_data(self, entity: str) -> Dict[str, float]:
        with self._lock:
            return dict(self._by_entity.get(entity, {}))

    def score(self, entity: str) -> Optional[float]:
        return self._scoring(self.entity_data(entity))

    def value(self) -> Optional[float]:
        """V(D): score of the single best-scoring entity."""
        with self._lock:
            entities = list(self._by_entity)
        scores = [s for s in (self.score(e) for e in entities)
                  if s is not None]
        return max(scores) if scores else None

    def cost(self) -> float:
        """C(D): total cost incurred."""
        with self._lock:
            return sum(o.cost for o in self._obs)

    def count(self, assay: Optional[str] = None) -> int:
        with self._lock:
            if assay is None:
                return len(self._obs)
            return sum(1 for o in self._obs if o.assay == assay)

    # -- checkpoint/restart ----------------------------------------------------

    def state(self) -> List[dict]:
        """Picklable/JSONable image of the record (for embedding in a
        campaign checkpoint alongside the queue snapshot)."""
        with self._lock:
            return [asdict(o) for o in self._obs]

    def load_state(self, data: List[dict]) -> int:
        """Atomically replace the record with ``data``.  Both structures
        are rebuilt off-lock and swapped under one lock hold, so a
        concurrent ``add`` observes either the old record or the fully
        restored one -- never a half-restored interleaving."""
        obs = [Observation(**d) for d in data]
        by_entity: Dict[str, Dict[str, float]] = {}
        for o in obs:
            by_entity.setdefault(o.entity, {})[o.prop] = o.value
        with self._lock:
            self._obs = obs
            self._by_entity = by_entity
        return len(obs)

    def save(self, path: str) -> None:
        data = self.state()
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(data, f)
        os.replace(tmp, path)

    def restore(self, path: str) -> int:
        with open(path) as f:
            data = json.load(f)
        return self.load_state(data)


# -- campaign-level checkpointing ------------------------------------------
#
# A campaign's durable state is two things: the record D (what has been
# observed) and the queue fabric (what is still in flight).  Checkpointing
# them together through ``ColmenaQueues.checkpoint`` gives a single file a
# ``kill -9``'d campaign resumes from without resubmitting completed work:
# queued tasks re-dispatch, leased (in-flight) tasks expire and redeliver,
# completed-but-unconsumed results deliver from the restored result
# queues, and the restored claim window swallows re-executions of work
# that already published a result.  When a Value Server is attached, its
# contents (both storage tiers, deduplicated across replicas) are bundled
# too, so proxied payloads survive the incarnation and restored task /
# result proxies resolve -- campaigns no longer trade the Value Server
# away to be checkpointable.


def checkpoint_campaign(path: str, queues, record: CampaignRecord,
                        extra=None) -> str:
    """Write record + queue state (+ Value Server contents, when one is
    attached) to ``path`` (atomic tmp+rename via
    ``ColmenaQueues.checkpoint``).  Cluster deployments checkpoint the
    same way: the queues' transport snapshot is then a *federation
    bundle* (every member broker's consistent cut) and the value-server
    snapshot spans the whole shard ring, so one file still resumes the
    whole cluster."""
    payload = {"record": record.state(), "extra": extra}
    return queues.checkpoint(path, extra=payload)


def resume_campaign(path: str, queues, record: CampaignRecord):
    """Restore ``path`` into a fresh fabric + record; returns the caller's
    ``extra``.  Call before task servers / Thinker agents start.

    ``path`` may also be a broker-side auto-snapshot (``snapshot_every``):
    those capture queue state only -- the record is left untouched (the
    application persists it separately, e.g. ``record.save``) and the
    returned ``extra`` is None."""
    payload = queues.resume(path)
    if payload is None:
        return None
    record.load_state(payload["record"])
    return payload["extra"]
