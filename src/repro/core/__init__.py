"""Colmena core: the paper's contribution as a composable library.

Thinker (multi-agent steering policies) <-> Task Server (dispatch, retry,
straggler mitigation) <-> Workers, with per-topic queues, a Value Server
for large-object transfer, pooled resource tracking, and the abstract
campaign formulation of §II-A.
"""
from repro.core.campaign import (AssaySpec, CampaignRecord,  # noqa: F401
                                 Observation, checkpoint_campaign,
                                 resume_campaign)
from repro.core.cluster import (ClusterLauncher, ClusterSpec,  # noqa: F401
                                HostSpec)
from repro.core.message import Intermediate, Result, Task  # noqa: F401
from repro.core.process_pool import ProcessPoolTaskServer  # noqa: F401
from repro.core.queues import ColmenaQueues  # noqa: F401
from repro.core.resources import ResourceTracker  # noqa: F401
from repro.core.streaming import (TaskCancelled,  # noqa: F401
                                  report_intermediate)
from repro.core.task_server import TaskServer  # noqa: F401
from repro.core.thinker import (BaseThinker, agent, event_responder,  # noqa: F401
                                result_processor)
from repro.core.transport.shards import ShardedValueServer  # noqa: F401
from repro.core.value_server import Proxy, ValueServer  # noqa: F401
