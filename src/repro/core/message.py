"""Task / Result messages with the paper's instrumented lifecycle (§III-C).

Every message carries a Timer recording serialization, queue transit,
dispatch and execution intervals -- the exact components the paper plots in
Fig. 5 -- plus payload sizes, so Thinker policies can reason about
communication overheads at plan time.

Payloads physically pass through pickle on enqueue/dequeue (as they do
through Redis in the paper) -- exactly once per queue hop: serialization
time and payload size are measured from the same bytes that travel, and
ride the queue envelope so the receiver can graft them onto the message's
Timer (see queues.py).  Large values can bypass the queue path via
Value-Server proxies (value_server.py), which is what Fig. 5/6 measure.
"""
from __future__ import annotations

import itertools
import pickle
import uuid
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.utils.timing import Timer, now

_id_counter = itertools.count()


def new_task_id() -> str:
    return f"task-{next(_id_counter)}-{uuid.uuid4().hex[:8]}"


@dataclass
class Task:
    topic: str                   # task type (assay name, "train", ...)
    method: str                  # registered function name at the Task Server
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)
    task_id: str = field(default_factory=new_task_id)
    timer: Timer = field(default_factory=Timer)
    input_size: int = 0          # serialized payload bytes
    retries: int = 0
    is_backup: bool = False      # straggler-mitigation duplicate
    exclude_worker: Optional[str] = None  # backup placement: not this worker
    bounces: int = 0             # times a worker declined (exclusion) so far


@dataclass
class Result:
    task_id: str
    topic: str
    method: str
    success: bool
    value: Any = None
    error: Optional[str] = None
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)
    timer: Timer = field(default_factory=Timer)
    input_size: int = 0
    output_size: int = 0
    worker: Optional[str] = None

    @property
    def task_runtime(self) -> float:
        return self.timer.intervals.get("execute", 0.0)

    def comm_overhead(self) -> float:
        """Total non-execution lifecycle time recorded so far."""
        return sum(v for k, v in self.timer.intervals.items()
                   if k != "execute")


@dataclass
class Intermediate:
    """A mid-task observation published by a worker over the ``stream``
    channel (the streaming-steering lane).  Rides the same single-pickle
    envelope as tasks/results, under the publishing task's trace; the
    Thinker's ``process_intermediate`` hook receives these and may
    ``queues.cancel(task_id)`` losers early to re-steer the capacity."""
    task_id: str
    topic: str
    seq: int                     # 0-based observation index within the task
    value: Any                   # the partial result (small; no shm lane)
    worker: Optional[str] = None


def serialize(obj) -> bytes:
    return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def deserialize(data: bytes):
    return pickle.loads(data)


def timed_serialize(obj, timer: Timer, name: str) -> bytes:
    t0 = now()
    data = serialize(obj)
    timer.record(name, now() - t0)
    return data


def timed_deserialize(data: bytes, timer: Timer, name: str):
    t0 = now()
    obj = deserialize(data)
    timer.record(name, now() - t0)
    return obj
