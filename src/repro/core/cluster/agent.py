"""The host agent: what actually runs on a (simulated or real) host.

One agent process per pool-running host.  It dials the host's *local*
broker, builds a ``ColmenaQueues`` over that connection, registers the
campaign's methods, and runs a ``ProcessPoolTaskServer`` with the host's
identity and per-topic backup peers -- then parks until told to stop
(SIGTERM; the launcher's ``stop``), shutting the pool down cleanly.

Simulated hosts are **forked** by the launcher, so method callables
(closures included) travel by inheritance; each agent makes itself a
process-group leader so a chaos ``kill_host`` can take out the agent
*and* its forked workers in one ``killpg`` -- exactly the blast radius
of a real node loss.

Real hosts run the same code via ``python -m repro.core.cluster.agent
--config <file>`` (see ``ClusterLauncher.ssh_commands``): the config is
a pickled ``AgentConfig`` whose methods are ``"module:qualname"``
strings resolved by import, since code cannot fork across machines.
"""
from __future__ import annotations

import importlib
import os
import signal
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro import observability as obs
from repro.core.process_pool import ProcessPoolTaskServer
from repro.core.queues import ColmenaQueues
from repro.core.transport.proc import ProcTransport


@dataclass
class AgentConfig:
    host: str
    pools: Dict[str, int]                   # topic -> worker count
    broker_address: tuple                   # this host's local broker
    lease_timeout: float = 30.0
    backup_hosts: Dict[str, List[str]] = field(default_factory=dict)
    # [(fn_or_"module:qualname", register_kwargs), ...]
    methods: list = field(default_factory=list)
    vs_addresses: Optional[list] = None     # Value Server shard addresses
    proxy_threshold: Optional[int] = None
    straggler_factor: Optional[float] = None
    straggler_min_history: int = 5
    # extra environment for this host (ClusterSpec.env_for): applied to
    # os.environ before the pool forks, so workers inherit it ahead of
    # their first jax/XLA import
    env: Dict[str, str] = field(default_factory=dict)


def resolve_method(fn):
    """A callable passes through (fork inheritance); a
    ``"module:qualname"`` string imports (the ssh path)."""
    if callable(fn):
        return fn
    mod, _, qual = fn.partition(":")
    obj = importlib.import_module(mod)
    for part in qual.split("."):
        obj = getattr(obj, part)
    return obj


def build_pool(cfg: AgentConfig) -> ProcessPoolTaskServer:
    transport = ProcTransport(address=cfg.broker_address,
                              lease_timeout=cfg.lease_timeout)
    vs = None
    if cfg.vs_addresses:
        from repro.core.transport.shards import ShardedValueServer
        # the ring (stable shard ids, epoch, replica factor) comes from
        # the shards themselves -- pushed there by the launcher -- so
        # every host's workers replicate and fail over identically, and
        # a post-rebalance agent restart adopts the current membership
        # even when its pickled address list has gone stale
        vs = ShardedValueServer.connect(cfg.vs_addresses)
    queues = ColmenaQueues(sorted(cfg.pools), transport=transport,
                           value_server=vs,
                           proxy_threshold=cfg.proxy_threshold)
    pool = ProcessPoolTaskServer(
        queues, workers_per_topic=dict(cfg.pools), host=cfg.host,
        backup_hosts=dict(cfg.backup_hosts),
        straggler_factor=cfg.straggler_factor,
        straggler_min_history=cfg.straggler_min_history,
        # control-event drain batch, sized to this host's worker count
        # (each in-flight task produces a couple of events)
        intake_batch=max(2 * max(cfg.pools.values(), default=1), 2))
    for fn, kwargs in cfg.methods:
        pool.register(resolve_method(fn), **kwargs)
    return pool


def host_agent_main(cfg: AgentConfig) -> None:
    """Process entry: run the host's pools until SIGTERM."""
    os.setpgrp()                            # killpg takes workers with us
    if cfg.env:
        # before the pool forks: workers inherit this, and XLA-style
        # variables only matter if set ahead of the first jax import
        os.environ.update(cfg.env)
    # claim the trace identity before build_pool's ColmenaQueues would
    # default this process to "thinker": the sink header is written once
    obs.configure(role="agent", host=cfg.host)
    stop = threading.Event()
    signal.signal(signal.SIGTERM, lambda *_: stop.set())
    pool = build_pool(cfg)
    try:
        with pool:
            stop.wait()
    except (ConnectionError, OSError):
        pass                                # broker died first: fabric gone
    os._exit(0)


def main(argv=None) -> None:
    import argparse
    import pickle
    p = argparse.ArgumentParser(
        description="Colmena cluster host agent (real-multi-host entry)")
    p.add_argument("--config", required=True,
                   help="pickled AgentConfig (methods as module:qualname)")
    args = p.parse_args(argv)
    with open(args.config, "rb") as f:
        cfg: AgentConfig = pickle.load(f)
    host_agent_main(cfg)


if __name__ == "__main__":
    main()
