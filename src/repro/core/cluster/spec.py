"""Declarative cluster topology: which hosts exist and what they run.

A ``ClusterSpec`` is a list of ``HostSpec``s -- name, whether the host
runs a broker, which worker pools (topic -> worker count), how many
Value Server shards, and whether the Thinker attaches there.  From it
the spec derives the two pieces of shared knowledge every federation
member must agree on byte-for-byte:

- ``broker_hosts``: the sorted list of hosts that run brokers (the
  federation membership; its first element is the **coordinator**, the
  broker that standalone claims route to and that runs the federation's
  auto-snapshot).
- ``partition()``: the topic -> home-broker map.  An application topic
  is homed at the broker of the first host (spec order) that pools it,
  so worker dispatch traffic stays on-host; per-host pool channels
  (``pool@<host>:...``) are homed at that host's broker by a naming
  rule the federation applies directly; anything else hashes
  deterministically across the broker hosts.

The spec is pure data (picklable): the launcher forks simulated hosts
that inherit it, and the ssh hook ships it to real hosts as a file.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# Where a tcmalloc shared object may live (Debian/Ubuntu layout).  The
# perf-env idiom only sets LD_PRELOAD when one actually exists: pointing
# the loader at a missing library stalls *every* exec on the host.
_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def perf_env_vars(n_local_workers: int) -> Dict[str, str]:
    """The HPC launcher environment idioms, as data:

    - ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` partitions
      the host CPU into one XLA device per local worker, so jax-based
      methods sharing a node each get a device instead of fighting over
      one.
    - tcmalloc via ``LD_PRELOAD`` (only when the library is actually
      installed), with its large-alloc report threshold raised so
      multi-GB device buffers don't spam stderr.
    - ``TF_CPP_MIN_LOG_LEVEL=4`` silences XLA's C++ chatter on worker
      stdout, which on a many-node run otherwise drowns the logs.

    ``LD_PRELOAD`` takes effect on *exec* -- it reaches agents launched
    over ssh (fresh interpreter) but not fork-only simulated hosts,
    which inherit the launcher's already-loaded allocator.  The XLA and
    logging variables just need to be set before the first jax/XLA
    import and work on both paths."""
    env = {
        "XLA_FLAGS": ("--xla_force_host_platform_device_count="
                      f"{max(n_local_workers, 1)}"),
        "TF_CPP_MIN_LOG_LEVEL": "4",
    }
    for so in _TCMALLOC_CANDIDATES:
        if os.path.exists(so):
            env["LD_PRELOAD"] = so
            env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = "60000000000"
            break
    return env


def host_hash_index(name: str, n: int) -> int:
    """Deterministic (process-independent) index of a string into n
    buckets -- md5, matching the Value Server's ring hashing rather than
    Python's salted ``hash``."""
    h = hashlib.md5(name.encode()).digest()
    return int.from_bytes(h[:8], "big") % n


@dataclass
class HostSpec:
    """One host and the roles it runs.

    address: a pre-bound broker address for real multi-host deployments
    (``("tcp", host, port)``); None lets the launcher bind one on
    loopback for a simulated host.  ssh: the ssh destination the real
    multi-host hook targets (``user@node``); None means this host is
    simulated as a local process group.  env: extra environment
    variables for this host's agent and inference shards, applied on
    top of the spec-level perf-env idioms (``ClusterSpec(perf_env=)``)
    so a per-host override always wins."""

    name: str
    broker: bool = True
    pools: Dict[str, int] = field(default_factory=dict)  # topic -> workers
    vs_shards: int = 0
    inference_shards: int = 0    # continuous-batching serving processes
    thinker: bool = False
    address: Optional[tuple] = None
    ssh: Optional[str] = None
    env: Dict[str, str] = field(default_factory=dict)


class ClusterSpec:
    def __init__(self, hosts: List[HostSpec], *,
                 partition: Optional[Dict[str, str]] = None,
                 lease_timeout: float = 30.0,
                 snapshot_every: float = 0.0,
                 snapshot_path: str = "",
                 vs_replicas: int = 1,
                 serve_topic: str = "infer",
                 perf_env: bool = False):
        """partition: explicit topic -> home-broker-host overrides (the
        derived default homes each topic at its first pool host).
        snapshot_every/snapshot_path: periodic auto-snapshot of the
        whole federation, written by the coordinator broker.
        vs_replicas: copies of every Value Server key across the shard
        ring (>=2 keeps keys readable through a shard/node loss; the
        launcher pushes the factor to the shards with the ring, so every
        connected client replicates identically).
        serve_topic: the inference request topic, relevant only when a
        host declares ``inference_shards``: the partition homes it at
        the first such host's broker so serving traffic stays on-host,
        and ``topics()`` registers it for connecting clients.
        perf_env: apply the launcher performance-environment idioms
        (``perf_env_vars``: per-worker XLA host devices, tcmalloc when
        installed, quiet XLA logging) to every host's agent and
        inference shards.  Off by default; ``HostSpec.env`` entries
        override it per host either way."""
        if not hosts:
            raise ValueError("a ClusterSpec needs at least one host")
        if vs_replicas < 1:
            raise ValueError("vs_replicas must be >= 1")
        total_shards = sum(h.vs_shards for h in hosts)
        if vs_replicas > 1 and total_shards and vs_replicas > total_shards:
            raise ValueError(
                f"vs_replicas={vs_replicas} exceeds the {total_shards}"
                " declared Value Server shard(s): a replica factor above"
                " the shard count cannot be satisfied")
        self.vs_replicas = vs_replicas
        self.serve_topic = serve_topic
        self.perf_env = perf_env
        bad_infer = [h.name for h in hosts if h.inference_shards < 0]
        if bad_infer:
            raise ValueError(
                f"negative inference_shards on hosts {bad_infer}")
        names = [h.name for h in hosts]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate host names in spec: {names}")
        for h in hosts:
            if "/" in h.name or ":" in h.name or "@" in h.name:
                raise ValueError(
                    f"host name {h.name!r} may not contain '/', ':' or '@'"
                    " (they delimit worker identities and pool channels)")
        self.hosts = list(hosts)
        self.lease_timeout = lease_timeout
        self.snapshot_every = snapshot_every
        self.snapshot_path = snapshot_path
        self._overrides = dict(partition or {})
        if not self.broker_hosts:
            raise ValueError("no host in the spec runs a broker")
        bad = [t for t, h in self._overrides.items()
               if h not in self.broker_hosts]
        if bad:
            raise ValueError(
                f"partition overrides {bad} name hosts without brokers")
        if snapshot_every and not snapshot_path:
            raise ValueError("snapshot_every is set but snapshot_path is"
                             " empty")
        thinkers = [h.name for h in hosts if h.thinker]
        if len(thinkers) > 1:
            raise ValueError(f"more than one thinker host: {thinkers}")

    # -- derived membership --------------------------------------------------

    @property
    def broker_hosts(self) -> List[str]:
        """Sorted: every federation member derives the identical list
        (and the identical coordinator, its first element)."""
        return sorted(h.name for h in self.hosts if h.broker)

    @property
    def coordinator(self) -> str:
        return self.broker_hosts[0]

    @property
    def thinker_host(self) -> str:
        """Where the Thinker attaches: the flagged host, else the
        coordinator.  (The Thinker itself is the caller's process; this
        only selects which broker it dials.)"""
        for h in self.hosts:
            if h.thinker:
                return h.name
        return self.coordinator

    def local_broker_of(self, name: str) -> str:
        """The broker a client on ``name`` dials: the host's own when it
        runs one, else the coordinator.  Shared by the launcher's agent
        wiring and ``connect`` so a brokerless host's clients always
        have a valid local broker."""
        return name if self.host(name).broker else self.coordinator

    def host(self, name: str) -> HostSpec:
        for h in self.hosts:
            if h.name == name:
                return h
        raise KeyError(name)

    def topics(self) -> List[str]:
        seen = []
        for h in self.hosts:
            for t in h.pools:
                if t not in seen:
                    seen.append(t)
        if self.inference_hosts and self.serve_topic not in seen:
            seen.append(self.serve_topic)
        return seen

    @property
    def inference_hosts(self) -> List[str]:
        """Hosts running inference shards, in spec order."""
        return [h.name for h in self.hosts if h.inference_shards > 0]

    def env_for(self, name: str) -> Dict[str, str]:
        """The environment the launcher applies to ``name``'s agent and
        inference shards: the perf-env idioms (when ``perf_env`` is on,
        sized to the host's own worker + shard count) overlaid with the
        host's explicit ``env`` map.  Empty when neither is set, so the
        default path touches nothing."""
        h = self.host(name)
        env: Dict[str, str] = {}
        if self.perf_env:
            n = sum(h.pools.values()) + h.inference_shards
            env.update(perf_env_vars(n))
        env.update(h.env)
        return env

    def pool_hosts(self, topic: str) -> List[str]:
        """Hosts running a pool for ``topic``, in spec order -- each
        pool's ``backup_hosts`` (cross-host straggler placement) is the
        others."""
        return [h.name for h in self.hosts if topic in h.pools]

    # -- the partition -------------------------------------------------------

    def partition(self) -> Dict[str, str]:
        """Topic -> home broker host for every application topic, with
        explicit overrides applied.  Default rule: the first host (spec
        order) pooling the topic that also runs a broker; else the
        coordinator.  Every broker and the launcher derive this from the
        same spec, which is what makes the federation's routing
        agreement total."""
        part: Dict[str, str] = {}
        for topic in self.topics():
            home = None
            for h in self.hosts:
                if topic in h.pools and h.broker:
                    home = h.name
                    break
                if (topic == self.serve_topic and h.inference_shards
                        and h.broker):
                    # serving traffic is homed with its first shard host
                    # for the same reason pool topics are: the shard's
                    # drain loop stays broker-local
                    home = h.name
                    break
            part[topic] = home or self.coordinator
        part.update(self._overrides)
        return part

    def home_of(self, topic: str) -> str:
        """Resolve any topic (application or generated pool channel) to
        its home broker -- the same rule ``FederatedBroker.home``
        applies frame by frame."""
        return resolve_home(topic, self.partition(), self.broker_hosts)


def resolve_home(topic: str, partition: Dict[str, str],
                 broker_hosts: List[str]) -> str:
    """Shared routing rule (spec side and broker side must never drift):
    explicit partition entry first; then per-host pool channels
    (``pool@<host>:...``, named by ``process_pool.dispatch_topic`` /
    ``control_topic``) home at that host's broker when it has one;
    everything else hashes deterministically over the broker hosts."""
    from repro.core.process_pool import POOL_PREFIX
    home = partition.get(topic)
    if home is not None:
        return home
    if topic.startswith(POOL_PREFIX):
        host = topic[len(POOL_PREFIX):].split(":", 1)[0]
        if host in broker_hosts:
            return host
    return broker_hosts[host_hash_index(topic, len(broker_hosts))]
