"""Multi-host cluster fabric (the paper's §V scale-out topology).

The transport fabric of ``repro.core.transport`` crosses *process*
boundaries; this package crosses *host* boundaries:

- ``spec``       -- declarative ``ClusterSpec``/``HostSpec``: which hosts
  exist, who runs a broker / worker pools / Value Server shards, where
  the Thinker attaches, and the derived topic partition every member
  agrees on.
- ``federation`` -- per-host brokers, each owning a partition of topics,
  with a verbatim-frame relay so any client reaches any topic through
  its local broker (one extra hop only for non-local topics; leases,
  claims and snapshots keep their exact single-broker semantics).
- ``launcher``   -- materializes the spec: simulated hosts as supervised
  local process groups over TCP, an ssh command hook for real hosts,
  rescue of a dead host's queued work, clean teardown.
- ``agent``      -- the per-host process that runs the pools.

Quick start (two simulated hosts)::

    from repro.core.cluster import ClusterSpec, HostSpec, ClusterLauncher

    spec = ClusterSpec([
        HostSpec("h0", pools={"simulate": 4}, thinker=True),
        HostSpec("h1", pools={"simulate": 4}),
    ])
    with ClusterLauncher(spec, methods=[(my_sim_fn,
                                         {"topic": "simulate"})]) as lc:
        queues = lc.connect()
        MyThinker(queues).run()
"""
from repro.core.cluster.launcher import ClusterLauncher  # noqa: F401
from repro.core.cluster.spec import ClusterSpec, HostSpec  # noqa: F401
