"""Materialize a ClusterSpec: brokers, shards, host agents, teardown.

``ClusterLauncher`` turns the declarative spec into running processes:

1. binds one TCP listening socket per broker host (in the launcher
   process, so by the time ``start`` returns every address is
   connectable -- no readiness race), then forks one
   ``federated_broker_main`` per member with the shared partition map
   and peer addresses; the coordinator also gets the federation's
   auto-snapshot config;
2. forks Value Server shard processes for hosts that declare
   ``vs_shards`` (the shard address list, in spec order, is the ring
   every client connects to);
3. forks one **host agent** per pool-running host (``cluster.agent``):
   a process-group-leader subprocess that dials its local broker and
   runs the host's ``ProcessPoolTaskServer`` -- the "simulated host".
   Real hosts instead run the same agent over ssh
   (``ssh_commands``/``write_agent_configs``);
4. tears everything down in reverse on ``stop`` (SIGTERM agents,
   shutdown frames to shards and brokers, a final shared-memory scope
   sweep for segments no registry could see).

Host failure needs no launcher-side rescue machinery on the direct
data plane: queued work only ever lives on the global request topics at
their home brokers (never relayed into per-host queues), and a dead
host's workers merely leave unacked leases there -- which expire and
redeliver to any surviving host's directly-subscribed workers.
Completions the dead host already published are deduped by the claim on
the result put: zero lost, zero duplicated, with nothing to supervise.

Every broker member is forked with the same shared-memory **scope
token**, so co-located clients can ride the shm payload lane
(``transport.shm``) against any member, and ``stop`` can sweep exactly
this cluster's leftover segments.

The Thinker lives in the *caller's* process: ``connect()`` returns a
``ColmenaQueues`` dialing the thinker host's broker; its channels
discover the federation's endpoints and dial each topic's home broker
directly, so steady-state task traffic takes zero relay hops end to end.
"""
from __future__ import annotations

import os
import pickle
import signal
import sys
import tempfile
import threading
from typing import Dict, List, Optional

from repro import observability as obs
from repro.core.cluster.agent import AgentConfig, host_agent_main
from repro.core.cluster.federation import federated_broker_main
from repro.core.cluster.spec import ClusterSpec, HostSpec
from repro.core.queues import ColmenaQueues
from repro.core.transport import frames, shm
from repro.core.transport.proc import ProcTransport
from repro.observability.monitor import CampaignMonitor

import multiprocessing

_mp = multiprocessing.get_context("fork")


class ClusterLauncher:
    def __init__(self, spec: ClusterSpec, methods=(), *,
                 proxy_threshold: Optional[int] = None,
                 straggler_factor: Optional[float] = None,
                 straggler_min_history: int = 5,
                 vs_capacity_bytes: Optional[int] = None,
                 vs_spill: bool = False,
                 serve_spec=None):
        """methods: ``[(fn, register_kwargs), ...]`` applied to every
        host pool (fn may be a ``"module:qualname"`` string for the ssh
        path).  proxy_threshold: forwarded to every host agent so
        workers proxy large *results* through the cluster's Value Server
        shards -- pass the same value to ``connect`` for the Thinker
        side.  straggler_factor / straggler_min_history: enable each
        host pool's straggler monitor (backups then prefer a different
        host).  vs_capacity_bytes / vs_spill: per-shard memory bound and
        spill-to-disk tier for the cluster's Value Server shards.
        serve_spec: a ``repro.serving.shard.ServeSpec`` for the hosts
        that declare ``inference_shards`` (required iff any does); its
        topic must match ``spec.serve_topic`` so the partition homes the
        serving traffic where the shards drain it."""
        self.spec = spec
        self.methods = list(methods)
        self.serve_spec = serve_spec
        if spec.inference_hosts:
            if serve_spec is None:
                raise ValueError(
                    f"hosts {spec.inference_hosts} declare inference"
                    " shards but the launcher got no serve_spec")
            if serve_spec.topic != spec.serve_topic:
                raise ValueError(
                    f"serve_spec.topic {serve_spec.topic!r} !="
                    f" spec.serve_topic {spec.serve_topic!r}: the"
                    " partition would home the traffic away from the"
                    " shards")
        self.proxy_threshold = proxy_threshold
        self.straggler_factor = straggler_factor
        self.straggler_min_history = straggler_min_history
        self.vs_capacity_bytes = vs_capacity_bytes
        self.vs_spill = vs_spill
        self._addresses: Dict[str, tuple] = {}
        self._brokers: Dict[str, _mp.Process] = {}
        self._agents: Dict[str, _mp.Process] = {}
        self._shards: list = []             # [{host, idx, sid, proc, addr}]
        self._infer_shards: list = []       # [{host, idx, proc}]
        self._next_sid = 0
        self.vs_addresses: list = []
        self._dir: Optional[str] = None
        self._stop = threading.Event()
        self._threads: list = []
        self._lock = threading.Lock()
        self._shm_scope: Optional[str] = None
        self.monitor: Optional[CampaignMonitor] = None

    # -- bring-up -----------------------------------------------------------

    def start(self) -> "ClusterLauncher":
        self._dir = tempfile.mkdtemp(prefix="colmena-cluster-")
        spec = self.spec
        # 1) bind every broker address first: the peer map must be
        # complete before any member starts
        socks = {}
        for name in spec.broker_hosts:
            h = spec.host(name)
            if h.address is not None:
                self._addresses[name] = tuple(h.address)  # external broker
                continue
            sock, addr = frames.make_server_socket(
                os.path.join(self._dir, f"{name}.sock"), tcp=True)
            socks[name] = sock
            self._addresses[name] = addr
        partition = spec.partition()
        # one shm scope for the whole cluster: every member advertises
        # it (endpoints op), co-located clients ride the payload lane
        # against any member, and stop() sweeps exactly these segments
        if shm.shm_dir() is not None:
            self._shm_scope = shm.new_scope()
        for name, sock in socks.items():
            every, path = 0.0, None
            if name == spec.coordinator and spec.snapshot_every:
                every, path = spec.snapshot_every, spec.snapshot_path
            p = _mp.Process(
                target=federated_broker_main,
                args=(sock, name, partition, dict(self._addresses),
                      every, path, self._shm_scope),
                daemon=True, name=f"colmena-broker-{name}")
            p.start()
            sock.close()
            self._brokers[name] = p
        # 2) Value Server shards (spec order -> the consistent-hash ring),
        # then push the versioned ring (stable sids + replica factor) to
        # every shard so connected clients agree on placement and stale
        # ones are redirected after a membership change
        for h in spec.hosts:
            for i in range(h.vs_shards):
                self._start_shard(h.name, i)
        if self._shards:
            self._push_vs_ring()
        # 2b) inference shards: forked and supervised like VS shards,
        # but they are *consumers* -- each dials its host's local broker
        # and drains the serve topic (homed there by the partition)
        for h in spec.hosts:
            for i in range(h.inference_shards):
                self._start_infer_shard(h.name, i)
        # 3) host agents (simulated hosts; ssh hosts are started by the
        # operator with ssh_commands)
        for h in spec.hosts:
            if h.pools and h.ssh is None:
                self._start_agent(h)
        # 4) the campaign monitor: a launcher-side daemon scraping every
        # broker's stats_scrape op on a cadence (live depth/lease/shm
        # gauges -> stats-monitor.jsonl next to the trace sinks)
        if obs.enabled():
            self.monitor = CampaignMonitor(dict(self._addresses),
                                           obs.obs_dir()).start()
        return self

    def _host_env(self, name: str) -> Dict[str, str]:
        """The environment a host's agent and inference shards get: the
        spec's map (perf-env idioms + per-host overrides) over an
        observability base.  The obs variables matter on both launch
        paths: forked processes inherit the launcher's REPRO_OBS_DIR /
        sample but need the per-host identity, and the ssh exec path
        inherits nothing at all."""
        env: Dict[str, str] = {}
        if obs.enabled():
            env[obs.ENV_DIR] = obs.obs_dir()
            env[obs.ENV_SAMPLE] = str(obs.sample_rate())
            env[obs.ENV_HOST] = name
        env.update(self.spec.env_for(name))
        return env

    def _start_shard(self, host: str, idx: int) -> dict:
        from repro.core.transport.shards import _shard_main
        sid = self._next_sid
        self._next_sid += 1
        sock, addr = frames.make_server_socket(
            os.path.join(self._dir, f"vs-{host}-{sid}.sock"), tcp=True)
        spill_dir = (os.path.join(self._dir, f"spill-{host}-{sid}")
                     if self.vs_spill else None)
        p = _mp.Process(target=_shard_main,
                        args=(sock, self.vs_capacity_bytes, spill_dir, None),
                        daemon=True, name=f"colmena-vs-{host}-{sid}")
        p.start()
        sock.close()
        entry = {"host": host, "idx": idx, "sid": sid, "proc": p,
                 "addr": addr}
        self._shards.append(entry)
        self.vs_addresses.append(addr)
        return entry

    def _start_infer_shard(self, host: str, idx: int) -> dict:
        from repro.serving.shard import start_inference_shard
        p = start_inference_shard(
            self._addresses[self.spec.local_broker_of(host)],
            self.serve_spec,
            lease_timeout=self.spec.lease_timeout,
            identity=f"infer@{host}:{idx}",
            env=self._host_env(host) or None)
        entry = {"host": host, "idx": idx, "proc": p}
        self._infer_shards.append(entry)
        return entry

    def _live_shards(self) -> list:
        return [e for e in self._shards if e["proc"].is_alive()]

    def _push_vs_ring(self) -> None:
        """Install ring epoch 1 on every shard: stable sids in spec
        order plus the spec's replica factor.  Every
        ``ShardedValueServer.connect`` then adopts the identical
        membership from the shards themselves."""
        ring = {"epoch": 1,
                "members": [(e["sid"], e["addr"]) for e in self._shards],
                "replicas": self.spec.vs_replicas}
        for e in self._shards:
            client = frames.FrameClient(e["addr"])
            try:
                client.request({"op": "vs_set_ring", "ring": ring},
                               retry=True)
            finally:
                client.close()

    def _agent_config(self, h: HostSpec) -> AgentConfig:
        backup = {t: [peer for peer in self.spec.pool_hosts(t)
                      if peer != h.name]
                  for t in h.pools}
        return AgentConfig(
            host=h.name, pools=dict(h.pools),
            broker_address=self._addresses[self.spec.local_broker_of(h.name)],
            lease_timeout=self.spec.lease_timeout,
            backup_hosts=backup, methods=list(self.methods),
            vs_addresses=list(self.vs_addresses) or None,
            proxy_threshold=self.proxy_threshold,
            straggler_factor=self.straggler_factor,
            straggler_min_history=self.straggler_min_history,
            env=self._host_env(h.name))

    def _start_agent(self, h: HostSpec) -> None:
        p = _mp.Process(target=host_agent_main, args=(self._agent_config(h),),
                        name=f"colmena-host-{h.name}")
        p.start()
        self._agents[h.name] = p

    # -- the real-multi-host hook -------------------------------------------

    def write_agent_configs(self, config_dir: str) -> Dict[str, str]:
        """Write one pickled AgentConfig per ssh host (methods must be
        ``"module:qualname"`` strings -- code cannot fork over ssh).
        Returns host -> config path."""
        os.makedirs(config_dir, exist_ok=True)
        out = {}
        for h in self.spec.hosts:
            if h.pools and h.ssh is not None:
                for fn, _ in self.methods:
                    if callable(fn):
                        raise ValueError(
                            f"host {h.name!r} launches over ssh: register"
                            " methods as 'module:qualname' strings, not"
                            " callables")
                path = os.path.join(config_dir, f"{h.name}.agent.pkl")
                with open(path, "wb") as f:
                    pickle.dump(self._agent_config(h), f,
                                protocol=pickle.HIGHEST_PROTOCOL)
                out[h.name] = path
        return out

    def ssh_commands(self, config_dir: str) -> Dict[str, List[str]]:
        """The command an operator (or a future auto-launcher) runs per
        real host: ship the host's config file there and exec the agent
        module against it.  Host environment (perf-env idioms +
        ``HostSpec.env``) rides an ``env`` prefix -- the exec path is
        the one where ``LD_PRELOAD``-style variables actually bite."""
        paths = self.write_agent_configs(config_dir)
        out = {}
        for name, path in paths.items():
            env = self._host_env(name)
            prefix = (["env"] + [f"{k}={v}" for k, v in sorted(env.items())]
                      if env else [])
            out[name] = (["ssh", self.spec.host(name).ssh] + prefix
                         + [sys.executable, "-m", "repro.core.cluster.agent",
                            "--config", path])
        return out

    # -- client-side wiring -------------------------------------------------

    def address_of(self, host: str) -> tuple:
        return self._addresses[host]

    def value_server(self):
        """A fresh client for the cluster's shard ring (None when the
        spec declares no shards).  The client adopts the launcher-pushed
        ring -- stable shard ids, current epoch, and the spec's
        ``vs_replicas`` factor -- from the shards themselves."""
        if not self.vs_addresses:
            return None
        from repro.core.transport.shards import ShardedValueServer
        return ShardedValueServer.connect(
            [e["addr"] for e in self._live_shards()] or self.vs_addresses)

    def connect(self, topics=None, **queues_kw) -> ColmenaQueues:
        """A ``ColmenaQueues`` dialing the thinker host's broker --
        construct the Thinker on it.  Pass ``value_server=`` /
        ``proxy_threshold=`` to proxy large payloads through the
        cluster's shards (``launcher.value_server()``)."""
        transport = ProcTransport(
            address=self.address_of(
                self.spec.local_broker_of(self.spec.thinker_host)),
            lease_timeout=self.spec.lease_timeout)
        return ColmenaQueues(topics or self.spec.topics(),
                             transport=transport, **queues_kw)

    # -- chaos ---------------------------------------------------------------

    def kill_host(self, host: str) -> None:
        """Chaos: SIGKILL the host's whole process group (agent + its
        forked workers -- a node loss) AND its Value Server and
        inference shard processes (they live on that node too).  No
        rescue follows: the dead workers' request-queue leases expire at
        their home brokers and redeliver straight to surviving hosts'
        directly-subscribed workers.  With ``spec.vs_replicas >= 2`` the
        dead VS shards' keys stay readable via their ring successors;
        ``restore_host_shards`` / ``restore_host_inference_shards``
        bring the capacity back afterwards.  A killed inference shard's
        in-flight request leases expire and redeliver to surviving
        shards; rows it already streamed out are deduped by the result
        claim."""
        self.spec.host(host)                # typo'd names raise, not no-op
        if (host not in self._agents
                and not any(e["host"] == host for e in self._shards)
                and not any(e["host"] == host
                            for e in self._infer_shards)):
            raise ValueError(
                f"host {host!r} runs neither a pool agent nor shards:"
                " nothing to kill (a silent no-op here would let a chaos"
                " test pass without injecting its fault)")
        p = self._agents.get(host)
        if p is not None:
            try:
                os.killpg(p.pid, signal.SIGKILL)
            except (ProcessLookupError, PermissionError):
                pass
            p.join(timeout=5)
        for e in self._shards:
            if e["host"] == host and e["proc"].is_alive():
                e["proc"].kill()
                e["proc"].join(timeout=2)
        for e in self._infer_shards:
            if e["host"] == host and e["proc"].is_alive():
                e["proc"].kill()
                e["proc"].join(timeout=2)

    def restore_host_inference_shards(self, host: str) -> list:
        """Refork every dead inference shard on ``host``.  No ring or
        state to rebuild: a shard is a stateless consumer, and the
        requests its predecessor died holding redeliver by lease expiry
        (to surviving shards, or to these replacements).  Returns the
        replacement entries."""
        dead = [e for e in self._infer_shards
                if e["host"] == host and not e["proc"].is_alive()]
        replaced = []
        for e in dead:
            self._infer_shards.remove(e)
            replaced.append(self._start_infer_shard(host, e["idx"]))
        return replaced

    def restore_host_shards(self, host: str) -> list:
        """Launcher-driven shard recovery: for every dead shard on
        ``host``, fork a replacement (fresh address), then drive one
        ring rebalance per replacement through a management client --
        the new shard joins, lost copies re-replicate from survivors,
        and the dead member leaves the ring.  Stale connected clients
        pick the new ring up via redirect frames on their next request.
        Returns the replacement entries."""
        from repro.core.transport.shards import ShardedValueServer
        dead = [e for e in self._shards
                if e["host"] == host and not e["proc"].is_alive()]
        if not dead:
            return []
        live = self._live_shards()
        if not live:
            raise RuntimeError("no surviving shard to rebalance from")
        # one management client for the whole recovery: its ring tracks
        # each replace_shard's epoch bump as it drives them
        mgmt = ShardedValueServer.connect([x["addr"] for x in live])
        replaced = []
        try:
            for e in dead:
                entry = self._start_shard(host, e["idx"])
                # adopt the sid the ring actually assigned (max+1 rule)
                # so launcher bookkeeping and ring membership never drift
                entry["sid"] = mgmt.replace_shard(e["sid"],
                                                  address=entry["addr"])
                self._next_sid = max(self._next_sid, entry["sid"] + 1)
                self._shards.remove(e)
                if e["addr"] in self.vs_addresses:
                    self.vs_addresses.remove(e["addr"])
                replaced.append(entry)
        finally:
            mgmt.close()
        return replaced

    # -- teardown -----------------------------------------------------------

    def stop(self) -> None:
        self._stop.set()
        if self.monitor is not None:
            # one last scrape while every broker is still up, so the
            # stats log always ends with a complete cluster-wide sample
            self.monitor.stop(final_scrape=True)
            self.monitor = None
        for name, p in self._agents.items():
            if p.is_alive():
                try:
                    os.kill(p.pid, signal.SIGTERM)
                except ProcessLookupError:
                    pass
        for name, p in self._agents.items():
            p.join(timeout=5)
            if p.is_alive():
                try:
                    os.killpg(p.pid, signal.SIGKILL)
                except (ProcessLookupError, PermissionError):
                    pass
                p.join(timeout=2)
        for e in self._infer_shards:
            if e["proc"].is_alive():
                e["proc"].terminate()   # SIGTERM: shard exits its loop
        for e in self._infer_shards:
            e["proc"].join(timeout=5)
            if e["proc"].is_alive():
                e["proc"].kill()
                e["proc"].join(timeout=2)
        for e in self._shards:
            try:
                frames.FrameClient(e["addr"]).request({"op": "shutdown"})
            except (ConnectionError, OSError):
                pass
            e["proc"].join(timeout=2)
            if e["proc"].is_alive():
                e["proc"].terminate()
        for name, p in self._brokers.items():
            try:
                frames.FrameClient(
                    self._addresses[name]).request({"op": "shutdown"})
            except (ConnectionError, OSError):
                pass
            p.join(timeout=2)
            if p.is_alive():
                p.terminate()
        for th in self._threads:
            th.join(timeout=2)
        if self._shm_scope is not None:
            # brokers released live segments on graceful shutdown; this
            # reclaims what no registry could see (producers that died
            # pre-handoff, SIGKILLed members) -- safe only now, with
            # every member down
            shm.sweep_scope(self._shm_scope)
        if self._dir is not None:
            import shutil
            shutil.rmtree(self._dir, ignore_errors=True)

    def __enter__(self) -> "ClusterLauncher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
