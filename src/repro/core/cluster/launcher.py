"""Materialize a ClusterSpec: brokers, shards, host agents, supervision.

``ClusterLauncher`` turns the declarative spec into running processes:

1. binds one TCP listening socket per broker host (in the launcher
   process, so by the time ``start`` returns every address is
   connectable -- no readiness race), then forks one
   ``federated_broker_main`` per member with the shared partition map
   and peer addresses; the coordinator also gets the federation's
   auto-snapshot config;
2. forks Value Server shard processes for hosts that declare
   ``vs_shards`` (the shard address list, in spec order, is the ring
   every client connects to);
3. forks one **host agent** per pool-running host (``cluster.agent``):
   a process-group-leader subprocess that dials its local broker and
   runs the host's ``ProcessPoolTaskServer`` -- the "simulated host".
   Real hosts instead run the same agent over ssh
   (``ssh_commands``/``write_agent_configs``);
4. supervises the agents: a monitor notices a dead host and starts a
   **rescue** drain that moves the dead host's still-queued dispatch
   envelopes back to their global request topics (bytes verbatim), so
   surviving hosts pick the work up.  In-flight leases held by the dead
   host's workers expire on their own and land in the same drain;
   completions the dead host already published are deduped by the claim
   on the result put -- zero lost, zero duplicated, same as every other
   failure mode in this fabric;
5. tears everything down in reverse on ``stop`` (SIGTERM agents,
   shutdown frames to shards and brokers).

The Thinker lives in the *caller's* process: ``connect()`` returns a
``ColmenaQueues`` dialing the thinker host's broker (one relay hop for
topics homed elsewhere -- by default a topic is homed with its first
pool host, so steady-state task traffic is broker-local to its workers).
"""
from __future__ import annotations

import os
import pickle
import signal
import sys
import tempfile
import threading
from typing import Dict, List, Optional

from repro.core.cluster.agent import AgentConfig, host_agent_main
from repro.core.cluster.federation import federated_broker_main
from repro.core.cluster.spec import ClusterSpec, HostSpec
from repro.core.process_pool import dispatch_topic
from repro.core.queues import ColmenaQueues
from repro.core.transport import frames
from repro.core.transport.proc import ProcTransport

import multiprocessing

_mp = multiprocessing.get_context("fork")


class ClusterLauncher:
    def __init__(self, spec: ClusterSpec, methods=(), *,
                 proxy_threshold: Optional[int] = None,
                 straggler_factor: Optional[float] = None,
                 straggler_min_history: int = 5,
                 vs_capacity_bytes: Optional[int] = None,
                 vs_spill: bool = False):
        """methods: ``[(fn, register_kwargs), ...]`` applied to every
        host pool (fn may be a ``"module:qualname"`` string for the ssh
        path).  proxy_threshold: forwarded to every host agent so
        workers proxy large *results* through the cluster's Value Server
        shards -- pass the same value to ``connect`` for the Thinker
        side.  straggler_factor / straggler_min_history: enable each
        host pool's straggler monitor (backups then prefer a different
        host).  vs_capacity_bytes / vs_spill: per-shard memory bound and
        spill-to-disk tier for the cluster's Value Server shards."""
        self.spec = spec
        self.methods = list(methods)
        self.proxy_threshold = proxy_threshold
        self.straggler_factor = straggler_factor
        self.straggler_min_history = straggler_min_history
        self.vs_capacity_bytes = vs_capacity_bytes
        self.vs_spill = vs_spill
        self._addresses: Dict[str, tuple] = {}
        self._brokers: Dict[str, _mp.Process] = {}
        self._agents: Dict[str, _mp.Process] = {}
        self._shards: list = []
        self.vs_addresses: list = []
        self._dir: Optional[str] = None
        self._stop = threading.Event()
        self._rescued: set = set()
        self._threads: list = []
        self._lock = threading.Lock()

    # -- bring-up -----------------------------------------------------------

    def start(self) -> "ClusterLauncher":
        self._dir = tempfile.mkdtemp(prefix="colmena-cluster-")
        spec = self.spec
        # 1) bind every broker address first: the peer map must be
        # complete before any member starts
        socks = {}
        for name in spec.broker_hosts:
            h = spec.host(name)
            if h.address is not None:
                self._addresses[name] = tuple(h.address)  # external broker
                continue
            sock, addr = frames.make_server_socket(
                os.path.join(self._dir, f"{name}.sock"), tcp=True)
            socks[name] = sock
            self._addresses[name] = addr
        partition = spec.partition()
        for name, sock in socks.items():
            every, path = 0.0, None
            if name == spec.coordinator and spec.snapshot_every:
                every, path = spec.snapshot_every, spec.snapshot_path
            p = _mp.Process(
                target=federated_broker_main,
                args=(sock, name, partition, dict(self._addresses),
                      every, path),
                daemon=True, name=f"colmena-broker-{name}")
            p.start()
            sock.close()
            self._brokers[name] = p
        # 2) Value Server shards (spec order -> the consistent-hash ring)
        for h in spec.hosts:
            for i in range(h.vs_shards):
                self._start_shard(h.name, i)
        # 3) host agents (simulated hosts; ssh hosts are started by the
        # operator with ssh_commands)
        for h in spec.hosts:
            if h.pools and h.ssh is None:
                self._start_agent(h)
        # 4) supervision
        th = threading.Thread(target=self._monitor_loop, daemon=True,
                              name="cluster-monitor")
        th.start()
        self._threads.append(th)
        return self

    def _start_shard(self, host: str, idx: int) -> None:
        from repro.core.transport.shards import _shard_main
        sock, addr = frames.make_server_socket(
            os.path.join(self._dir, f"vs-{host}-{idx}.sock"), tcp=True)
        spill_dir = (os.path.join(self._dir, f"spill-{host}-{idx}")
                     if self.vs_spill else None)
        p = _mp.Process(target=_shard_main,
                        args=(sock, self.vs_capacity_bytes, spill_dir, None),
                        daemon=True, name=f"colmena-vs-{host}-{idx}")
        p.start()
        sock.close()
        self._shards.append((p, addr))
        self.vs_addresses.append(addr)

    def _agent_config(self, h: HostSpec) -> AgentConfig:
        backup = {t: [peer for peer in self.spec.pool_hosts(t)
                      if peer != h.name]
                  for t in h.pools}
        return AgentConfig(
            host=h.name, pools=dict(h.pools),
            broker_address=self._addresses[self.spec.local_broker_of(h.name)],
            lease_timeout=self.spec.lease_timeout,
            backup_hosts=backup, methods=list(self.methods),
            vs_addresses=list(self.vs_addresses) or None,
            proxy_threshold=self.proxy_threshold,
            straggler_factor=self.straggler_factor,
            straggler_min_history=self.straggler_min_history)

    def _start_agent(self, h: HostSpec) -> None:
        p = _mp.Process(target=host_agent_main, args=(self._agent_config(h),),
                        name=f"colmena-host-{h.name}")
        p.start()
        self._agents[h.name] = p

    # -- the real-multi-host hook -------------------------------------------

    def write_agent_configs(self, config_dir: str) -> Dict[str, str]:
        """Write one pickled AgentConfig per ssh host (methods must be
        ``"module:qualname"`` strings -- code cannot fork over ssh).
        Returns host -> config path."""
        os.makedirs(config_dir, exist_ok=True)
        out = {}
        for h in self.spec.hosts:
            if h.pools and h.ssh is not None:
                for fn, _ in self.methods:
                    if callable(fn):
                        raise ValueError(
                            f"host {h.name!r} launches over ssh: register"
                            " methods as 'module:qualname' strings, not"
                            " callables")
                path = os.path.join(config_dir, f"{h.name}.agent.pkl")
                with open(path, "wb") as f:
                    pickle.dump(self._agent_config(h), f,
                                protocol=pickle.HIGHEST_PROTOCOL)
                out[h.name] = path
        return out

    def ssh_commands(self, config_dir: str) -> Dict[str, List[str]]:
        """The command an operator (or a future auto-launcher) runs per
        real host: ship the host's config file there and exec the agent
        module against it."""
        paths = self.write_agent_configs(config_dir)
        return {name: ["ssh", self.spec.host(name).ssh, sys.executable,
                       "-m", "repro.core.cluster.agent", "--config", path]
                for name, path in paths.items()}

    # -- client-side wiring -------------------------------------------------

    def address_of(self, host: str) -> tuple:
        return self._addresses[host]

    def value_server(self):
        """A fresh client for the cluster's shard ring (None when the
        spec declares no shards)."""
        if not self.vs_addresses:
            return None
        from repro.core.transport.shards import ShardedValueServer
        return ShardedValueServer.connect(self.vs_addresses)

    def connect(self, topics=None, **queues_kw) -> ColmenaQueues:
        """A ``ColmenaQueues`` dialing the thinker host's broker --
        construct the Thinker on it.  Pass ``value_server=`` /
        ``proxy_threshold=`` to proxy large payloads through the
        cluster's shards (``launcher.value_server()``)."""
        transport = ProcTransport(
            address=self.address_of(
                self.spec.local_broker_of(self.spec.thinker_host)),
            lease_timeout=self.spec.lease_timeout)
        return ColmenaQueues(topics or self.spec.topics(),
                             transport=transport, **queues_kw)

    # -- supervision / chaos ------------------------------------------------

    def _monitor_loop(self) -> None:
        while not self._stop.wait(0.25):
            for name, p in list(self._agents.items()):
                if not p.is_alive():
                    self._start_rescue(name)

    def _start_rescue(self, host: str) -> None:
        """Idempotently begin draining a dead host's dispatch channels
        back into the global request topics."""
        with self._lock:
            if host in self._rescued:
                return
            self._rescued.add(host)
        th = threading.Thread(target=self._rescue_loop,
                              args=(self.spec.host(host),),
                              daemon=True, name=f"cluster-rescue-{host}")
        th.start()
        self._threads.append(th)

    def _rescue_loop(self, h: HostSpec) -> None:
        """The dead host's dispatch queues hold (a) envelopes its intake
        relayed but no worker picked up, immediately drainable, and (b)
        envelopes whose worker died holding the lease -- those surface
        here when the lease expires (our own gets run the expiry).  Each
        is re-put -- bytes verbatim -- on its topic's global request
        queue, where a surviving host's intake leases it.  A completion
        the dead worker managed to publish first makes the re-execution
        lose the claim: exactly-once holds."""
        t = ProcTransport(
            address=self._addresses[self.spec.coordinator],
            lease_timeout=self.spec.lease_timeout)
        pairs = [(t.channel(dispatch_topic(h.name, topic), "tasks"),
                  t.channel(topic, "requests")) for topic in h.pools]
        while not self._stop.is_set():
            for disp, req in pairs:
                try:
                    envs = disp.get_batch(32, timeout=0.25,
                                          cancel=self._stop)
                    if not envs:
                        continue
                    for env in envs:
                        if env.meta.get("stop"):
                            continue        # a shutdown marker, not work
                        req.put(env)
                    disp.ack()
                except (ConnectionError, OSError, RuntimeError):
                    return                  # fabric is gone
        t.client.close()

    def kill_host(self, host: str) -> None:
        """Chaos: SIGKILL the host's whole process group (agent + its
        forked workers -- a node loss), then start the rescue drain."""
        p = self._agents[host]
        try:
            os.killpg(p.pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass
        p.join(timeout=5)
        self._start_rescue(host)

    # -- teardown -----------------------------------------------------------

    def stop(self) -> None:
        self._stop.set()
        for name, p in self._agents.items():
            if p.is_alive():
                try:
                    os.kill(p.pid, signal.SIGTERM)
                except ProcessLookupError:
                    pass
        for name, p in self._agents.items():
            p.join(timeout=5)
            if p.is_alive():
                try:
                    os.killpg(p.pid, signal.SIGKILL)
                except (ProcessLookupError, PermissionError):
                    pass
                p.join(timeout=2)
        for p, addr in self._shards:
            try:
                frames.FrameClient(addr).request({"op": "shutdown"})
            except (ConnectionError, OSError):
                pass
            p.join(timeout=2)
            if p.is_alive():
                p.terminate()
        for name, p in self._brokers.items():
            try:
                frames.FrameClient(
                    self._addresses[name]).request({"op": "shutdown"})
            except (ConnectionError, OSError):
                pass
            p.join(timeout=2)
            if p.is_alive():
                p.terminate()
        for th in self._threads:
            th.join(timeout=2)
        if self._dir is not None:
            import shutil
            shutil.rmtree(self._dir, ignore_errors=True)

    def __enter__(self) -> "ClusterLauncher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
