"""Broker federation: per-host brokers, each owning a partition of topics.

Every host's broker is a plain ``Broker`` wrapped in a relay layer.  A
client only ever dials its *local* broker; when a frame addresses a
topic homed elsewhere, the local broker forwards the frame **verbatim**
(header minus the already-routed acks, payload bytes untouched) to the
home broker and relays the response back -- one extra hop, and only for
non-local topics.  Because the envelope payload is never touched and the
lease/claim/epoch state lives solely at the home broker, every fabric
guarantee survives federation unchanged:

- a relayed ``get`` parks this connection's handler thread inside the
  home broker's queue Condition (blocking + batching on the wire, no
  polling anywhere);
- the lease a relayed get returns is the home broker's; acks route back
  by topic -- including acks *piggybacked* on frames for other topics,
  which the relay splits by home and forwards (a forwarded ack lost to
  a dead peer merely leaves a lease to expire, which claim dedup makes
  safe);
- ``put(..., claim=)`` runs atomically at the home broker, so
  exactly-once completion arbitration is untouched;
- ``wake`` broadcasts to every member (relayed wakes carry a ``fed``
  flag so they are applied locally and never re-broadcast -- no storms);
- ``snapshot``/``restore`` operate on the whole federation: any member
  bundles its own snapshot with its peers' (each internally a consistent
  cut) into one blob, and ``restore`` unbundles it back out.  Taken from
  the application's blessed checkpoint site (no concurrent submits or
  unquiesced consumers mid-relay), the bundle is a resumable image of
  the whole cluster -- the same file format ``ColmenaQueues.checkpoint``
  wraps.  A campaign checkpoint pairs this bundle with a Value Server
  ring snapshot (``transport.shards``), so proxied payloads resume with
  the queues that reference them: restoring either half without the
  other is what used to force inline payloads, and no longer happens.

Standalone ``claim`` (no topic to route by) goes to the federation
coordinator.  The shipped task servers never use it -- completion claims
ride ``put(..., claim=)`` and arbitrate at the result topic's home -- so
the two paths cannot disagree about an id; callers that mix them across
topics homed off-coordinator would forfeit that and should not.

All members derive routing from the same ``ClusterSpec`` (partition map
+ sorted broker-host list), which is what makes the agreement total: a
relayed frame is always local at its target, so relay chains have
length exactly one.
"""
from __future__ import annotations

import pickle
import threading
from typing import Dict, Optional, Tuple

from repro import observability as obs
from repro.core.cluster.spec import host_hash_index, resolve_home
from repro.core.transport import frames
from repro.core.transport.broker import Broker, start_autosnapshot

FED_SNAPSHOT_VERSION = 1


def dump_fed_snapshot(host_snaps: Dict[str, bytes]) -> bytes:
    """Bundle per-broker snapshots into one blob.  Hosts are sorted so
    identical federation state always produces identical bytes (each
    member snapshot is itself deterministic)."""
    return pickle.dumps(
        {"fed_snapshot": FED_SNAPSHOT_VERSION,
         "hosts": dict(sorted(host_snaps.items()))},
        protocol=pickle.HIGHEST_PROTOCOL)


def is_fed_snapshot(payload: dict) -> bool:
    return isinstance(payload, dict) and "fed_snapshot" in payload


class FederatedBroker:
    """One member of the federation: a local ``Broker`` plus the relay.

    ``peers`` maps every broker host (including this one) to its
    address; relays open one connection per (handler-thread, peer) via
    ``FrameClient``'s per-thread sockets, so a parked relayed get only
    occupies its own connection on both sides."""

    def __init__(self, host: str, partition: Dict[str, str],
                 peers: Dict[str, tuple], shm_scope: Optional[str] = None):
        self.host = host
        self.partition = dict(partition)
        self.broker_hosts = sorted(peers)
        if host not in peers:
            raise ValueError(f"own host {host!r} missing from peer map")
        self.broker = Broker(shm_scope=shm_scope)
        self.peer_addresses = dict(peers)
        self._peers = {h: frames.FrameClient(addr)
                       for h, addr in peers.items() if h != host}

    def home(self, topic: str) -> str:
        return resolve_home(topic, self.partition, self.broker_hosts)

    # -- relay plumbing -----------------------------------------------------

    def _route_acks(self, header: dict) -> dict:
        """Apply local piggybacked acks, forward the rest to their home
        brokers (as fed ack frames), and return the header stripped of
        them.  Runs before the op itself, preserving the broker's
        commit-before-op ordering for the local share; a forwarding
        failure only strands a lease for expiry + claim dedup."""
        acks = header.get("acks", ())
        if not acks:
            return header
        remote: Dict[str, list] = {}
        for topic, kind, lid in acks:
            h = self.home(topic)
            if h == self.host:
                self.broker.ack(topic, kind, lid)
            else:
                remote.setdefault(h, []).append((topic, kind, lid))
        for h, racks in remote.items():
            try:
                self._peers[h].request(
                    {"op": "ack", "fed": True, "acks": racks})
            except (ConnectionError, OSError, RuntimeError):
                pass
        header = dict(header)
        header.pop("acks", None)
        return header

    def _relay(self, h: str, header: dict,
               payload: bytes) -> Tuple[dict, bytes]:
        fh = dict(header)
        fh["fed"] = True
        return self._peers[h].request(fh, payload)

    # -- federation-wide ops ------------------------------------------------

    def fed_snapshot(self) -> bytes:
        snaps = {self.host: self.broker.snapshot()}
        for h, client in sorted(self._peers.items()):
            _, snap = client.request({"op": "snapshot", "fed": True},
                                     retry=True)
            snaps[h] = snap
        return dump_fed_snapshot(snaps)

    def fed_restore(self, payload: bytes, expire_leases: bool) -> None:
        # control-plane decode: the payload IS a federation snapshot
        # bundle this layer owns, not a relayed task envelope
        # fabriclint: skip=frame-header-hygiene -- snapshot bundle, not an envelope
        state = pickle.loads(payload)
        if not is_fed_snapshot(state):
            # a single-broker snapshot restores into the local member
            self.broker.restore(payload, expire_leases)
            return
        if state["fed_snapshot"] != FED_SNAPSHOT_VERSION:
            raise ValueError("unsupported federation snapshot version "
                             f"{state['fed_snapshot']!r}")
        unknown = set(state["hosts"]) - set(self.broker_hosts)
        if unknown:
            raise ValueError(
                f"snapshot names brokers not in this federation: "
                f"{sorted(unknown)}")
        for h, snap in state["hosts"].items():
            if h == self.host:
                self.broker.restore(snap, expire_leases)
            else:
                self._peers[h].request(
                    {"op": "restore", "fed": True,
                     "expire_leases": expire_leases}, snap, retry=True)

    def fed_wake(self) -> None:
        self.broker.wake()
        for client in self._peers.values():
            try:
                client.request({"op": "wake", "fed": True}, retry=True)
            except (ConnectionError, OSError, RuntimeError):
                pass            # dead peer: nothing parked there anyway

    # -- frame dispatch -----------------------------------------------------

    def handle(self, header: dict,
               payload: bytes) -> Optional[Tuple[dict, bytes]]:
        if header.get("fed"):
            # already routed by a peer: strictly local (length-one chains)
            return self.broker.handle(header, payload)
        header = self._route_acks(header)
        op = header["op"]
        # cancel/put_stream/cancelled route like the data-plane ops: a
        # topic's requests/results/stream queues AND its slice of the
        # cancelled window all live at the topic's home broker, so the
        # cancel claim and the completion's fused put-claim arbitrate in
        # one place
        if op in ("put", "get", "len", "renew", "backup",
                  "cancel", "put_stream", "cancelled"):
            h = self.home(header["topic"])
            if h != self.host:
                return self._relay(h, header, payload)
            return self.broker.handle(header, payload)
        if op == "endpoints":
            # advertise the whole federation so clients open their own
            # connection to each topic's home broker (relay chains of
            # length zero on the data plane); the relay path above stays
            # as the compatibility fallback for clients that don't
            import socket as socketlib
            return {"host": self.host, "peers": dict(self.peer_addresses),
                    "partition": dict(self.partition),
                    "machine": socketlib.gethostname(),
                    "scope": self.broker.shm_scope}, b""
        if op == "wake":
            self.fed_wake()
            return {"ok": True}, b""
        if op == "claim":
            h = self.broker_hosts[0]        # the coordinator (see module doc)
            if h != self.host:
                return self._relay(h, header, payload)
            return self.broker.handle(header, payload)
        if op == "snapshot":
            return {"ok": True}, self.fed_snapshot()
        if op == "restore":
            self.fed_restore(payload, header.get("expire_leases", False))
            return {"ok": True}, b""
        # ack (the explicit-flush carrier), ping, shutdown, unknown ops
        return self.broker.handle(header, payload)


def federated_broker_main(sock, host: str, partition: Dict[str, str],
                          peers: Dict[str, tuple],
                          snapshot_every: float = 0.0,
                          snapshot_path: Optional[str] = None,
                          shm_scope: Optional[str] = None) -> None:
    """Entry point of one federation member's broker process.  Only the
    coordinator is given ``snapshot_every``: its auto-snapshot bundles
    the *whole federation* into one resumable file."""
    fb = FederatedBroker(host, partition, peers, shm_scope=shm_scope)
    # identify this member on the fabric timeline; non-coordinators
    # calibrate their clock against the coordinator so the report can
    # compose every process's offset chain to one root
    coord = sorted(peers)[0]
    ref, offset = "", None
    if obs.enabled() and coord != host and coord in fb._peers:
        def _probe() -> float:
            hdr, _ = fb._peers[coord].request({"op": "clock_sync"},
                                              retry=True)
            return float(hdr["t"])
        try:
            offset = obs.calibrate(_probe)
            ref = obs.addr_str(peers[coord])
        except (ConnectionError, OSError, RuntimeError, KeyError,
                TypeError, ValueError):
            offset = None                   # telemetry only: never fatal
    obs.configure(role="broker", host=host,
                  addr=obs.addr_str(peers.get(host, "")),
                  ref=ref, offset=offset)
    stop = threading.Event()
    if snapshot_every and snapshot_path:
        start_autosnapshot(fb.fed_snapshot, snapshot_every, snapshot_path,
                           stop)
    frames.serve_forever(sock, fb.handle, stop)
    fb.broker.release_segments()


__all__ = ["FederatedBroker", "federated_broker_main", "dump_fed_snapshot",
           "is_fed_snapshot", "host_hash_index"]
