"""Worker-side streaming context: ``report_intermediate`` + cooperative
cancel (the task-function half of the streaming-steering lane).

A task function running under a streaming-aware task server publishes
mid-task observations and becomes preemptible between publishes::

    from repro.core import streaming

    def simulate(mol, steps):
        for i in range(steps):
            partial = advance(mol)
            # rides the topic's ``stream`` channel under the task's
            # lease; raises TaskCancelled the moment the Thinker culls
            # this task (the publish is fused with the cancel probe)
            streaming.report_intermediate(partial)
        return finish(mol)

The task server installs a ``TaskContext`` around the user function
(thread-local, so nested/parallel executions cannot cross wires) and
catches ``TaskCancelled``: no result is published and the dispatch lease
is detached, never acked -- a genuinely cancelled task's lease was
already revoked broker-side, and a wrongly-interrupted one redelivers
via lease expiry, so exactly-once is preserved either way.  Outside a
task server (plain function call, unit test) ``report_intermediate`` is
a no-op, so task functions stay runnable anywhere.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro import observability as obs
from repro.core.message import Intermediate, serialize
from repro.core.transport.base import Channel, Envelope
from repro.utils.timing import now


class TaskCancelled(Exception):
    """The current task was preempted (broker-side ``cancel``): unwind
    out of the user function now.  Task servers catch this above the
    user frame -- it must never be swallowed into the retry path."""


class TaskContext:
    """Per-execution streaming state.  ``cancel_pending`` is a one-cell
    list shared with the worker's signal/heartbeat machinery: it flips
    True when a cancel arrives at a moment the exception cannot be
    raised (outside the user function), and the next
    ``report_intermediate`` converts it."""

    def __init__(self, task_id: str, topic: str,
                 stream: Optional[Channel] = None, traced: bool = False,
                 worker: Optional[str] = None,
                 cancel_pending: Optional[list] = None):
        self.task_id = task_id
        self.topic = topic
        self.stream = stream            # the topic's ``stream`` channel
        self.traced = bool(traced)
        self.worker = worker
        self.cancel_pending = (cancel_pending if cancel_pending is not None
                               else [False])
        self.seq = 0

    def check_cancelled(self) -> None:
        if self.cancel_pending[0]:
            raise TaskCancelled(self.task_id)

    def report_intermediate(self, value) -> None:
        self.check_cancelled()
        if self.stream is None:
            return
        msg = Intermediate(task_id=self.task_id, topic=self.topic,
                           seq=self.seq, value=value, worker=self.worker)
        self.seq += 1
        t0 = now()
        data = serialize(msg)
        meta = {"task_id": self.task_id, "seq": msg.seq}
        if self.traced:
            meta["trace"] = True
        cancelled = self.stream.put_stream(Envelope(now(), data, meta),
                                           self.task_id)
        if cancelled:
            # the fused probe says this task is already cancelled: the
            # observation was dropped broker-side -- abort here
            raise TaskCancelled(self.task_id)
        obs.counter("observations").inc()
        if self.traced:
            obs.span(self.task_id, "report_intermediate", t0, now(),
                     seq=msg.seq)


_tls = threading.local()


def set_context(ctx: Optional[TaskContext]) -> None:
    _tls.ctx = ctx


def clear_context() -> None:
    _tls.ctx = None


def current_context() -> Optional[TaskContext]:
    return getattr(_tls, "ctx", None)


def report_intermediate(value) -> None:
    """Publish a mid-task observation onto the executing task's stream
    lane.  Raises ``TaskCancelled`` when the task has been preempted
    (pending cooperative flag, or the fused publish-probe's answer).
    Outside a streaming-aware task server this is a no-op."""
    ctx = current_context()
    if ctx is not None:
        ctx.report_intermediate(value)


__all__ = ["TaskCancelled", "TaskContext", "set_context", "clear_context",
           "current_context", "report_intermediate"]
