"""Value Server with lazy object proxies (paper §III-B3).

Large task inputs/results bypass the Thinker <-> Task Server queue path:
the value is placed in a key-value store and replaced by a small ``Proxy``.
Proxies are lazy -- cheap to serialize and to pass around; the value is
fetched only when first used.  Workers keep a local proxy cache (re-used
inputs such as ML model weights are fetched once per worker) and can
*asynchronously pre-resolve* proxies so the fetch overlaps with task
startup (paper: "communication with the Value Server is overlapped with the
task's execution").

Lifecycle management (long-campaign posture): entries carry a refcount and
the store keeps LRU order.  One-shot payloads created by the queue layer
(``proxy_tree(one_shot=True)``) are pinned with one reference and released
by the consumer once resolved, so per-task inputs/results are deleted
instead of accumulating over a campaign.  Independently, a
``capacity_bytes`` bound evicts least-recently-used *unreferenced* entries
(e.g. superseded model weights) on insert; pinned entries are never
evicted.

Spill tier: with ``spill_dir`` set, capacity evictions land in a file
store (one pickle per key) instead of being discarded, and a later ``get``
faults the entry back into the memory tier byte-identically (possibly
spilling something else to make room).  This turns ``capacity_bytes`` from
a destructive bound into a working-set bound, which is what the sharded
deployment (``transport.shards``) runs per shard.

Spill I/O is **staged outside the store lock**: a fault-in (or eviction
write) marks its key in-flight, releases the lock for the ~ms disk
read/write, and re-acquires it only to publish the entry -- so a shard
thrashing its capacity bound no longer serializes every unrelated
``get``/``put`` behind the disk.  Any operation touching an in-flight key
waits on the store condition until the marker clears, which keeps the
per-key linearizability the locked implementation had (a concurrent
``get`` of a key mid-spill waits and then faults it back; it can never
observe the key missing).

TPU adaptation note (DESIGN.md §2): on a real pod the store holds
device-resident jax.Arrays and resolution is a device-to-device copy; in
this container the store is an in-process dict with a configurable
simulated fetch bandwidth so SynApp can reproduce the paper's Fig. 5/6
crossover behaviour honestly.
"""
from __future__ import annotations

import os
import pickle
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Iterator, Optional
import uuid

from repro.utils.timing import now


class _Entry:
    __slots__ = ("value", "size", "refs")

    def __init__(self, value, size: int, refs: int):
        self.value = value
        self.size = size
        self.refs = refs


class ValueServer:
    def __init__(self, *, fetch_bandwidth: Optional[float] = None,
                 capacity_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        """fetch_bandwidth: simulated bytes/s for fetches (None = no wait).
        capacity_bytes: LRU-evict unreferenced entries past this bound
        (None = unbounded, matching the original behaviour).
        spill_dir: evictions spill to files here (created if missing) and
        fault back in on ``get`` instead of being discarded."""
        self._store: "OrderedDict[str, _Entry]" = OrderedDict()
        self._lock = threading.Lock()
        # notified whenever a key's in-flight spill I/O marker clears;
        # shares the store lock so `with self._lock` sections compose
        self._io_done = threading.Condition(self._lock)
        self._io_keys: set = set()          # keys with staged disk I/O
        self._resolver = ThreadPoolExecutor(max_workers=4,
                                            thread_name_prefix="vs-resolve")
        self.fetch_bandwidth = fetch_bandwidth
        self.capacity_bytes = capacity_bytes
        self.spill_dir = spill_dir
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
        self._spilled: dict = {}            # key -> [size, refs]
        self._bytes = 0
        self.stats = {"puts": 0, "gets": 0, "bytes_put": 0, "bytes_get": 0,
                      "evictions": 0, "deletes": 0, "spills": 0,
                      "spill_hits": 0}

    def _await_key_locked(self, key: str) -> None:
        """Block (lock held, released while waiting) until no staged
        spill I/O is in flight for ``key`` -- afterwards the key is back
        in exactly one of the two tiers and the caller can proceed as if
        the I/O had happened atomically."""
        while key in self._io_keys:
            self._io_done.wait()

    def put(self, value, *, size: Optional[int] = None, refs: int = 0,
            key: Optional[str] = None) -> str:
        """key: adopt a caller-minted key (the sharded deployment mints
        keys client-side so consistent-hash routing needs no handshake)."""
        key = key or uuid.uuid4().hex
        if size is None:
            # arrays are sized from their buffer (matching the sharded
            # deployment's typed codec bytes); a pickle of a large device
            # array just to measure it would defeat the pickle-free path
            from repro.core.transport import ndcodec
            size = ndcodec.nbytes_of(value)
            if size is None:
                size = len(pickle.dumps(value,
                                        protocol=pickle.HIGHEST_PROTOCOL))
        with self._lock:
            self._await_key_locked(key)
            # putting over an existing key replaces it wholesale: the old
            # entry's size must leave the accounting (and a stale spill
            # copy must leave the disk), or restore/rebalance re-puts
            # would inflate _bytes until the LRU thrashes live entries
            old = self._store.pop(key, None)
            if old is not None:
                self._bytes -= old.size
            if self._spilled.pop(key, None) is not None:
                self._remove_spill_file(key)
            self._store[key] = _Entry(value, size, refs)
            self._bytes += size
            self.stats["puts"] += 1
            self.stats["bytes_put"] += size
        # capacity enforcement happens after the insert is published: the
        # store can transiently exceed the bound by one entry while the
        # eviction writes its spill file outside the lock
        self._evict(protect=key)
        return key

    def get(self, key: str):
        entry = None
        with self._lock:
            self._await_key_locked(key)
            entry = self._store.get(key)
            if entry is not None:
                self._store.move_to_end(key)
                self.stats["gets"] += 1
                self.stats["bytes_get"] += entry.size
                value, size = entry.value, entry.size
            else:
                if key not in self._spilled:
                    raise KeyError(key)
                # stage the fault-in: claim the key, drop the lock for
                # the disk read, publish the entry on re-acquire --
                # unrelated ops proceed during the read; ops on THIS key
                # wait on the in-flight marker
                size, refs = self._spilled.pop(key)
                self._io_keys.add(key)
        if entry is None:
            try:
                value = self._read_spill(key)
            except BaseException:
                with self._lock:            # undo the claim: still spilled
                    self._spilled[key] = [size, refs]
                    self._io_keys.discard(key)
                    self._io_done.notify_all()
                raise
            self._remove_spill_file(key)
            with self._lock:
                self._store[key] = _Entry(value, size, refs)
                self._bytes += size
                self.stats["spill_hits"] += 1
                self.stats["gets"] += 1
                self.stats["bytes_get"] += size
                self._io_keys.discard(key)
                self._io_done.notify_all()
            self._evict(protect=key)        # may spill something else
        if self.fetch_bandwidth:
            import time
            time.sleep(size / self.fetch_bandwidth)
        return value

    def size_of(self, key: str) -> int:
        with self._lock:
            self._await_key_locked(key)
            if key in self._spilled:
                return self._spilled[key][0]
            return self._store[key].size

    # -- lifetime -----------------------------------------------------------

    def add_ref(self, key: str) -> None:
        with self._lock:
            self._await_key_locked(key)
            spilled = self._spilled.get(key)
            if spilled is not None and key not in self._store:
                # pure metadata update: no reason to pay the disk fault-in
                # here -- the refs ride the spill index and are restored
                # when a get brings the entry back
                spilled[1] += 1
                return
            self._store[key].refs += 1

    def release(self, key: str) -> bool:
        """Drop one reference; delete the entry once unreferenced.
        Returns True if the entry was deleted (missing keys are a no-op)."""
        with self._lock:
            self._await_key_locked(key)
            entry = self._store.get(key)
            if entry is None:
                spilled = self._spilled.get(key)
                if spilled is None:
                    return False
                spilled[1] -= 1
                if spilled[1] > 0:
                    return False
                del self._spilled[key]
                self._remove_spill_file(key)
                self.stats["deletes"] += 1
                return True
            entry.refs -= 1
            if entry.refs > 0:
                return False
            del self._store[key]
            self._bytes -= entry.size
            self.stats["deletes"] += 1
            return True

    def delete(self, key: str) -> None:
        with self._lock:
            self._await_key_locked(key)
            entry = self._store.pop(key, None)
            if entry is not None:
                self._bytes -= entry.size
            elif self._spilled.pop(key, None) is not None:
                self._remove_spill_file(key)

    # -- durability: inventory / migration / snapshot -------------------------

    def keys_info(self) -> list:
        """``[(key, size, refs, tier)]`` across both tiers (tier is
        ``"mem"`` or ``"spill"``).  Waits out staged spill I/O first so a
        key mid-transition is never missed -- this is what shard
        rebalancing enumerates before migrating."""
        with self._lock:
            while self._io_keys:
                self._io_done.wait()
            out = [(k, e.size, e.refs, "mem") for k, e in self._store.items()]
            out.extend((k, size, refs, "spill")
                       for k, (size, refs) in self._spilled.items())
            return out

    def info_of(self, key: str) -> tuple:
        """(size, refs, tier) of one key (KeyError when absent)."""
        with self._lock:
            self._await_key_locked(key)
            entry = self._store.get(key)
            if entry is not None:
                return entry.size, entry.refs, "mem"
            size, refs = self._spilled[key]
            return size, refs, "spill"

    def peek(self, key: str) -> tuple:
        """(value, size, refs) without changing tiers: a spilled entry is
        read from its file under the lock (like ``snapshot``) instead of
        being faulted into memory -- migration exports must not evict
        other entries, delete the on-disk copy, or pay the simulated
        fetch bandwidth just to copy bytes off a shard."""
        with self._lock:
            self._await_key_locked(key)
            entry = self._store.get(key)
            if entry is not None:
                return entry.value, entry.size, entry.refs
            if key not in self._spilled:
                raise KeyError(key)
            size, refs = self._spilled[key]
            return self._read_spill(key), size, refs

    def detach_spilled(self, key: str) -> tuple:
        """Forget a *spilled* entry without deleting its file; returns
        (size, refs).  The migration fast path: when source and
        destination shards share a filesystem, the caller renames the
        spill file into the destination's spill dir and ``adopt_spilled``
        registers it there -- the payload bytes never cross a socket.
        KeyError when the key is not currently in the spill tier (the
        caller falls back to the export/re-put path)."""
        with self._lock:
            self._await_key_locked(key)
            if key in self._store or key not in self._spilled:
                raise KeyError(key)
            size, refs = self._spilled.pop(key)
            return size, refs

    def adopt_spilled(self, key: str, size: int, refs: int) -> None:
        """Register a key whose spill file was placed at
        ``_spill_path(key)`` by a migration rename (counterpart of
        ``detach_spilled``)."""
        assert self.spill_dir is not None, "adopting requires a spill tier"
        with self._lock:
            self._await_key_locked(key)
            self._spilled[key] = [size, refs]
            self.stats["puts"] += 1
            self.stats["bytes_put"] += size

    def snapshot(self) -> bytes:
        """Deterministic image of the whole store: a sorted list of
        ``(key, value, size, refs)`` covering both tiers (spilled values
        are read from their files -- the snapshot reuses the spill
        tier's on-disk pickle format without faulting anything back into
        memory).  Identical contents always produce identical bytes, so
        checkpoint files stay comparable across incarnations.

        The whole capture -- spill-file reads included -- runs under the
        store lock: a concurrent ``get`` fault-in or ``release`` removes
        spill files, and reading them unlocked could race that removal
        mid-snapshot.  Serializing other ops behind a (rare) checkpoint
        is the price of the cut being consistent."""
        with self._lock:
            while self._io_keys:
                self._io_done.wait()
            entries = {k: (k, e.value, e.size, e.refs)
                       for k, e in self._store.items()}
            for k, (size, refs) in self._spilled.items():
                entries[k] = (k, self._read_spill(k), size, refs)
            return pickle.dumps(
                {"version": 1,
                 "entries": [entries[k] for k in sorted(entries)]},
                protocol=pickle.HIGHEST_PROTOCOL)

    def restore(self, data: bytes) -> int:
        """Re-put every entry of a ``snapshot`` (keys and refcounts
        preserved; capacity/spill policy re-applied on the way in).
        Returns the number of entries restored.

        Also accepts a *sharded* snapshot (``ShardedValueServer``):
        there the entry values are the client's pickle bytes, so they
        are unpickled on the way in -- a checkpoint taken on the proc
        backend restores onto an in-process deployment and vice versa."""
        state = pickle.loads(data)
        if state.get("version") != 1:
            raise ValueError("unsupported value-server snapshot version "
                             f"{state.get('version')!r}")
        sharded = state.get("sharded", False)
        for key, value, size, refs in state["entries"]:
            if sharded:
                value = pickle.loads(value)
            self.put(value, size=size, refs=refs, key=key)
        return len(state["entries"])

    # -- spill tier ---------------------------------------------------------

    def _spill_path(self, key: str) -> str:
        return os.path.join(self.spill_dir, key + ".pkl")

    def _remove_spill_file(self, key: str) -> None:
        try:
            os.remove(self._spill_path(key))
        except OSError:
            pass

    def _read_spill(self, key: str):
        """One spill-file read; factored out so tests can slow it down
        to observe that staged I/O no longer blocks unrelated ops."""
        with open(self._spill_path(key), "rb") as f:
            return pickle.loads(f.read())

    def _write_spill(self, key: str, value) -> None:
        with open(self._spill_path(key), "wb") as f:
            f.write(pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))

    def _evict(self, protect: Optional[str] = None) -> None:
        """Bring the memory tier back under ``capacity_bytes``.  Victims
        are chosen and unlinked from the store under the lock; the spill
        *write* happens outside it with the victim's in-flight marker
        set, so concurrent ops on other keys never queue behind the
        disk.  Re-checked per iteration: concurrent evictors cannot pick
        the same victim (the pop removes it before the lock drops)."""
        if self.capacity_bytes is None:
            return
        while True:
            with self._lock:
                if self._bytes <= self.capacity_bytes:
                    return
                victim = next((k for k, e in self._store.items()
                               if e.refs <= 0 and k != protect), None)
                if victim is None:
                    return                  # everything left is pinned
                entry = self._store.pop(victim)
                self._bytes -= entry.size
                self.stats["evictions"] += 1
                if self.spill_dir is None:
                    continue                # destructive bound: discarded
                self._io_keys.add(victim)
            try:
                self._write_spill(victim, entry.value)
            except BaseException:
                with self._lock:            # failed write: keep it resident
                    self._store[victim] = entry
                    self._bytes += entry.size
                    self.stats["evictions"] -= 1
                    self._io_keys.discard(victim)
                    self._io_done.notify_all()
                raise
            with self._lock:
                self._spilled[victim] = [entry.size, 0]
                self.stats["spills"] += 1
                self._io_keys.discard(victim)
                self._io_done.notify_all()

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return self._bytes

    @property
    def spilled_bytes(self) -> int:
        with self._lock:
            return sum(size for size, _ in self._spilled.values())

    def __len__(self) -> int:
        with self._lock:
            return len(self._store) + len(self._spilled)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            self._await_key_locked(key)
            return key in self._store or key in self._spilled

    def prefetch(self, key: str) -> Future:
        return self._resolver.submit(self.get, key)


class Proxy:
    """Lazy reference to a value in a ValueServer.

    Pickles as (key, size, one_shot) only; `resolve(server)` (or attribute
    access once bound) fetches and memoizes the value.  A worker-level cache
    can be attached via `bind` so repeated uses hit local memory.
    ``one_shot`` marks proxies minted by the queue layer for a single
    task/result payload; the fabric releases their store entry after the
    consumer resolves them.
    """

    __slots__ = ("key", "size", "one_shot", "_server", "_value", "_resolved",
                 "_future")

    def __init__(self, key: str, size: int, one_shot: bool = False):
        self.key = key
        self.size = size
        self.one_shot = one_shot
        self._server = None
        self._value = None
        self._resolved = False
        self._future = None

    # -- lifecycle ----------------------------------------------------------

    def bind(self, server: ValueServer, cache: Optional[dict] = None,
             async_resolve: bool = False) -> "Proxy":
        self._server = (server, cache)
        if async_resolve and not self._resolved:
            if cache is not None and self.key in cache:
                pass
            else:
                self._future = server.prefetch(self.key)
        return self

    def resolve(self, server: Optional[ValueServer] = None):
        if self._resolved:
            return self._value
        srv, cache = (self._server if self._server is not None
                      else (server, None))
        if srv is None and server is not None:
            srv, cache = server, None
        assert srv is not None, "unbound proxy"
        if cache is not None and self.key in cache:
            value = cache[self.key]
        elif self._future is not None:
            value = self._future.result()
        else:
            value = srv.get(self.key)
        # one-shot payloads have a single consumer: caching them would turn
        # the worker cache into the unbounded campaign-memory leak the
        # refcounted store deletion exists to prevent
        if cache is not None and not self.one_shot:
            cache[self.key] = value
        self._value = value
        self._resolved = True
        self._future = None
        return value

    # -- pickle: ship only the reference -------------------------------------

    def __reduce__(self):
        return (Proxy, (self.key, self.size, self.one_shot))

    def __repr__(self):
        state = "resolved" if self._resolved else "lazy"
        return f"Proxy(key={self.key[:8]}, size={self.size}, {state})"


# ---------------------------------------------------------------------------
# Tree helpers used by the queue layer
# ---------------------------------------------------------------------------


def _leaf_size(value) -> int:
    """Quick size estimate without a full pickle for arrays."""
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    try:
        return len(pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))
    except Exception:
        return 0


def iter_proxies(obj) -> Iterator[Proxy]:
    """Yield Proxy leaves of a (shallow) container tree."""
    if isinstance(obj, (tuple, list)):
        leaves = obj
    elif isinstance(obj, dict):
        leaves = obj.values()
    else:
        leaves = (obj,)
    for v in leaves:
        if isinstance(v, Proxy):
            yield v


def proxy_tree(obj, server: ValueServer, threshold: int, timer=None,
               prefix: str = "proxy", one_shot: bool = False):
    """Replace any value (or container element) above `threshold` bytes with
    a Proxy.  Containers handled: tuple, list, dict (one level is enough for
    task args/kwargs and result values).  ``one_shot=True`` pins the store
    entry with one reference and marks the proxy so the fabric can release
    it after its single consumer resolves it."""
    t0 = now()
    refs = 1 if one_shot else 0

    def one(v):
        size = _leaf_size(v)
        if size >= threshold and not isinstance(v, Proxy):
            return Proxy(server.put(v, size=size, refs=refs), size,
                         one_shot=one_shot)
        return v

    if isinstance(obj, tuple):
        out = tuple(one(v) for v in obj)
    elif isinstance(obj, list):
        out = [one(v) for v in obj]
    elif isinstance(obj, dict):
        out = {k: one(v) for k, v in obj.items()}
    else:
        out = one(obj)
    if timer is not None:
        timer.record(prefix + "_put", now() - t0)
    return out


def resolve_tree(obj, server: Optional[ValueServer],
                 cache: Optional[dict] = None, async_start: bool = False):
    """Resolve proxies in a (shallow) container tree."""
    def one(v):
        if isinstance(v, Proxy):
            if async_start:
                return v.bind(server, cache, async_resolve=True)
            return v.bind(server, cache).resolve()
        return v

    if isinstance(obj, tuple):
        return tuple(one(v) for v in obj)
    if isinstance(obj, list):
        return [one(v) for v in obj]
    if isinstance(obj, dict):
        return {k: one(v) for k, v in obj.items()}
    return one(obj)
