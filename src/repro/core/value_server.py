"""Value Server with lazy object proxies (paper §III-B3).

Large task inputs/results bypass the Thinker <-> Task Server queue path:
the value is placed in a key-value store and replaced by a small ``Proxy``.
Proxies are lazy -- cheap to serialize and to pass around; the value is
fetched only when first used.  Workers keep a local proxy cache (re-used
inputs such as ML model weights are fetched once per worker) and can
*asynchronously pre-resolve* proxies so the fetch overlaps with task
startup (paper: "communication with the Value Server is overlapped with the
task's execution").

TPU adaptation note (DESIGN.md §2): on a real pod the store holds
device-resident jax.Arrays and resolution is a device-to-device copy; in
this container the store is an in-process dict with a configurable
simulated fetch bandwidth so SynApp can reproduce the paper's Fig. 5/6
crossover behaviour honestly.
"""
from __future__ import annotations

import pickle
import threading
import uuid
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

from repro.utils.timing import now


class ValueServer:
    def __init__(self, *, fetch_bandwidth: Optional[float] = None):
        """fetch_bandwidth: simulated bytes/s for fetches (None = no wait)."""
        self._store: dict = {}
        self._sizes: dict = {}
        self._lock = threading.Lock()
        self._resolver = ThreadPoolExecutor(max_workers=4,
                                            thread_name_prefix="vs-resolve")
        self.fetch_bandwidth = fetch_bandwidth
        self.stats = {"puts": 0, "gets": 0, "bytes_put": 0, "bytes_get": 0}

    def put(self, value, *, size: Optional[int] = None) -> str:
        key = uuid.uuid4().hex
        if size is None:
            size = len(pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))
        with self._lock:
            self._store[key] = value
            self._sizes[key] = size
            self.stats["puts"] += 1
            self.stats["bytes_put"] += size
        return key

    def get(self, key: str):
        with self._lock:
            value = self._store[key]
            size = self._sizes[key]
            self.stats["gets"] += 1
            self.stats["bytes_get"] += size
        if self.fetch_bandwidth:
            import time
            time.sleep(size / self.fetch_bandwidth)
        return value

    def size_of(self, key: str) -> int:
        with self._lock:
            return self._sizes[key]

    def delete(self, key: str) -> None:
        with self._lock:
            self._store.pop(key, None)
            self._sizes.pop(key, None)

    def prefetch(self, key: str) -> Future:
        return self._resolver.submit(self.get, key)


class Proxy:
    """Lazy reference to a value in a ValueServer.

    Pickles as (key, size) only; `resolve(server)` (or attribute access once
    bound) fetches and memoizes the value.  A worker-level cache can be
    attached via `bind` so repeated uses hit local memory.
    """

    __slots__ = ("key", "size", "_server", "_value", "_resolved", "_future")

    def __init__(self, key: str, size: int):
        self.key = key
        self.size = size
        self._server = None
        self._value = None
        self._resolved = False
        self._future = None

    # -- lifecycle ----------------------------------------------------------

    def bind(self, server: ValueServer, cache: Optional[dict] = None,
             async_resolve: bool = False) -> "Proxy":
        self._server = (server, cache)
        if async_resolve and not self._resolved:
            if cache is not None and self.key in cache:
                pass
            else:
                self._future = server.prefetch(self.key)
        return self

    def resolve(self, server: Optional[ValueServer] = None):
        if self._resolved:
            return self._value
        srv, cache = (self._server if self._server is not None
                      else (server, None))
        if srv is None and server is not None:
            srv, cache = server, None
        assert srv is not None, "unbound proxy"
        if cache is not None and self.key in cache:
            value = cache[self.key]
        elif self._future is not None:
            value = self._future.result()
        else:
            value = srv.get(self.key)
        if cache is not None:
            cache[self.key] = value
        self._value = value
        self._resolved = True
        self._future = None
        return value

    # -- pickle: ship only the reference -------------------------------------

    def __reduce__(self):
        return (Proxy, (self.key, self.size))

    def __repr__(self):
        state = "resolved" if self._resolved else "lazy"
        return f"Proxy(key={self.key[:8]}, size={self.size}, {state})"


# ---------------------------------------------------------------------------
# Tree helpers used by the queue layer
# ---------------------------------------------------------------------------


def _leaf_size(value) -> int:
    """Quick size estimate without a full pickle for arrays."""
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    try:
        return len(pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))
    except Exception:
        return 0


def proxy_tree(obj, server: ValueServer, threshold: int, timer=None,
               prefix: str = "proxy"):
    """Replace any value (or container element) above `threshold` bytes with
    a Proxy.  Containers handled: tuple, list, dict (one level is enough for
    task args/kwargs and result values)."""
    t0 = now()

    def one(v):
        size = _leaf_size(v)
        if size >= threshold and not isinstance(v, Proxy):
            return Proxy(server.put(v, size=size), size)
        return v

    if isinstance(obj, tuple):
        out = tuple(one(v) for v in obj)
    elif isinstance(obj, list):
        out = [one(v) for v in obj]
    elif isinstance(obj, dict):
        out = {k: one(v) for k, v in obj.items()}
    else:
        out = one(obj)
    if timer is not None:
        timer.record(prefix + "_put", now() - t0)
    return out


def resolve_tree(obj, server: Optional[ValueServer],
                 cache: Optional[dict] = None, async_start: bool = False):
    """Resolve proxies in a (shallow) container tree."""
    def one(v):
        if isinstance(v, Proxy):
            if async_start:
                return v.bind(server, cache, async_resolve=True)
            return v.bind(server, cache).resolve()
        return v

    if isinstance(obj, tuple):
        return tuple(one(v) for v in obj)
    if isinstance(obj, list):
        return [one(v) for v in obj]
    if isinstance(obj, dict):
        return {k: one(v) for k, v in obj.items()}
    return one(obj)
