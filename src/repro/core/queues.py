"""Per-topic request/result queue pairs (the paper's Redis topology).

The Thinker writes Tasks to the request queue of a topic; the Task Server
reads them, executes, and writes Results to the topic's result queue.
Distinct queue pairs per task type simplify multi-agent Thinkers (§III-B3).

Messages physically traverse pickle bytes so the serialization /
communication costs the paper measures are real, not simulated.  A
configurable proxy threshold transparently moves large values through the
Value Server instead (lazy object proxies).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterable, Optional

from repro.core import message as msg
from repro.core.value_server import ValueServer, proxy_tree, resolve_tree
from repro.utils.timing import now


class TopicQueue:
    def __init__(self):
        self.requests: "queue.Queue[bytes]" = queue.Queue()
        self.results: "queue.Queue[bytes]" = queue.Queue()


class ColmenaQueues:
    """The Thinker <-> Task Server communication fabric."""

    def __init__(self, topics: Iterable[str], *,
                 value_server: Optional[ValueServer] = None,
                 proxy_threshold: Optional[int] = None):
        self._topics = {t: TopicQueue() for t in topics}
        self.value_server = value_server
        self.proxy_threshold = proxy_threshold
        self._active = 0
        self._lock = threading.Lock()
        self._all_done = threading.Condition(self._lock)

    def topics(self):
        return list(self._topics)

    # -- Thinker side -------------------------------------------------------

    def send_task(self, *args, method: str, topic: str = "default",
                  **kwargs) -> str:
        task = msg.Task(topic=topic, method=method, args=args, kwargs=kwargs)
        task.timer.mark("created")
        if self.value_server is not None and self.proxy_threshold is not None:
            task.args = proxy_tree(task.args, self.value_server,
                                   self.proxy_threshold, task.timer)
            task.kwargs = proxy_tree(task.kwargs, self.value_server,
                                     self.proxy_threshold, task.timer)
        data = msg.timed_serialize(task, task.timer, "serialize_request")
        task.input_size = len(data)
        # re-serialize so the receiver sees the recorded size/time
        data = msg.serialize(task)
        with self._lock:
            self._active += 1
        q = self._topics[task.topic]
        q.requests.put((now(), data))
        return task.task_id

    def get_result(self, topic: str = "default",
                   timeout: Optional[float] = None) -> Optional[msg.Result]:
        q = self._topics[topic]
        try:
            t_put, data = q.results.get(timeout=timeout)
        except queue.Empty:
            return None
        result = msg.deserialize(data)
        result.timer.record("result_queue_transit", now() - t_put)
        t0 = now()
        result.value = resolve_tree(result.value, self.value_server)
        result.timer.record("deserialize_result", now() - t0)
        with self._lock:
            self._active -= 1
            if self._active <= 0:
                self._all_done.notify_all()
        return result

    def wait_until_done(self, timeout: Optional[float] = None) -> bool:
        with self._lock:
            if self._active <= 0:
                return True
            return self._all_done.wait(timeout)

    @property
    def active_count(self) -> int:
        with self._lock:
            return self._active

    # -- Task Server side ---------------------------------------------------

    def get_task(self, topic: str,
                 timeout: Optional[float] = None) -> Optional[msg.Task]:
        q = self._topics[topic]
        try:
            t_put, data = q.requests.get(timeout=timeout)
        except queue.Empty:
            return None
        task = msg.deserialize(data)
        task.timer.record("request_queue_transit", now() - t_put)
        task.timer.mark("received_by_server")
        return task

    def send_result(self, result: msg.Result) -> None:
        if self.value_server is not None and self.proxy_threshold is not None:
            result.value = proxy_tree(result.value, self.value_server,
                                      self.proxy_threshold, result.timer,
                                      prefix="serialize_result")
        data = msg.timed_serialize(result, result.timer, "serialize_result")
        result.output_size = len(data)
        data = msg.serialize(result)
        self._topics[result.topic].results.put((now(), data))

    def requeue(self, task: msg.Task) -> None:
        """Retry path: put a (deserialized) task back on its request queue."""
        data = msg.serialize(task)
        self._topics[task.topic].requests.put((now(), data))
