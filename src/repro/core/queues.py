"""Per-topic request/result queue pairs (the paper's Redis topology).

The Thinker writes Tasks to the request queue of a topic; the Task Server
reads them, executes, and writes Results to the topic's result queue.
Distinct queue pairs per task type simplify multi-agent Thinkers (§III-B3).

Messages physically traverse pickle bytes so the serialization /
communication costs the paper measures are real, not simulated.  Each
message is serialized **exactly once** per queue hop: the pickled payload
travels inside a tiny envelope that carries the enqueue timestamp plus the
serialization time / payload size measured from those same bytes, and the
receiver grafts them onto the deserialized message's Timer.

*Where* the envelope waits is a pluggable transport backend
(``repro.core.transport``):

- ``backend="local"`` -- in-process ``Condition``-notified deques:
  consumers block until a producer notifies them, ``wake_all()`` nudges
  every blocked consumer so shutdown events propagate immediately, and
  batched drains (``get_tasks`` / ``get_results``) amortize wakeups.
- ``backend="proc"`` -- the envelope's single-pickle bytes become a
  socket frame to a broker process, so Thinker and Task Server can be
  different OS processes (the paper's multi-process topology) with the
  exact same call-site API and the same blocking/batching semantics.

A configurable proxy threshold transparently moves large values through the
Value Server instead (lazy object proxies); those one-shot entries are
refcounted and released once their single consumer resolves them.

Delivery is leased on both backends (``transport.base.Channel``): the
queue-level ``get_*`` helpers ack as soon as a batch is decoded and
handed to the caller, while raw-channel consumers (pool workers) hold
their lease across execution -- either way an unacked batch redelivers
after ``lease_timeout``, and ``checkpoint(path)``/``resume(path)``
persist the whole fabric (queued + in-flight envelopes, claim window,
active count) so a killed campaign restarts without resubmission.
"""
from __future__ import annotations

import os
import pickle
import tempfile
import threading
from typing import Iterable, List, Optional

from repro import observability as obs
from repro.core import message as msg
from repro.core.transport import Envelope, Transport, make_transport
from repro.core.value_server import iter_proxies, proxy_tree, resolve_tree
from repro.utils.timing import now


class TopicQueue:
    def __init__(self, transport: Transport, topic: str):
        self.requests = transport.channel(topic, "requests")
        self.results = transport.channel(topic, "results")
        # mid-task observations (streaming steering): workers publish
        # via the fused ``put_stream`` under the task's lease, Thinkers
        # drain via ``get_intermediates`` / ``process_intermediate``
        self.stream = transport.channel(topic, "stream")


class ColmenaQueues:
    """The Thinker <-> Task Server communication fabric."""

    def __init__(self, topics: Iterable[str], *,
                 backend: str = "local",
                 transport: Optional[Transport] = None,
                 value_server=None,
                 proxy_threshold: Optional[int] = None,
                 release_inputs: bool = True,
                 lease_timeout: Optional[float] = None,
                 snapshot_every: float = 0.0,
                 snapshot_path: str = "",
                 serve_spec=None,
                 trace=None,
                 trace_dir: str = ""):
        """backend: "local" (in-process deques) or "proc" (socket broker
        process); ignored when an explicit ``transport`` is given.
        release_inputs: delete one-shot proxied task inputs from the
        Value Server once the task completes (bounds campaign memory).
        Set False if your Thinker resolves ``result.args`` proxies after
        completion, e.g. to resubmit the exact input payload.
        lease_timeout: seconds before an unacked delivery lease expires
        and its envelopes redeliver (None: the backend default).  Must
        exceed the longest task execution *or* the consumer must renew
        (pool workers heartbeat); it also bounds how long a resumed
        campaign waits before re-running work that was in flight at the
        checkpoint.
        serve_spec: a ``repro.serving.shard.ServeSpec`` declaring the
        fabric's inference topic -- registers the topic's queue pair and
        makes it ``send_inference``'s default destination.  The shards
        that drain it are forked by the cluster launcher (or
        ``start_inference_shard``); this side only routes requests.
        snapshot_every/snapshot_path (proc backend): the forked broker
        auto-snapshots its whole state to ``snapshot_path`` every
        ``snapshot_every`` seconds (atomic tmp+rename) -- long campaigns
        get a crash-resumable file (``resume`` accepts it directly) with
        no application checkpoint call.
        trace: distributed tracing sampling control.  ``True`` enables
        span sinks at the default sample rate
        (``observability.DEFAULT_SAMPLE``); a float in (0, 1] sets the
        rate; ``0``/``False`` force tracing off; ``None`` (default)
        inherits the environment (``REPRO_OBS_DIR``/``REPRO_OBS_SAMPLE``
        -- how cluster-launched roles get theirs).  trace_dir: sink
        directory (default: env, else a fresh temp dir, exposed as
        ``self.trace_dir`` for ``repro.observability.report``).  The
        sampling decision is made once per task here and rides the
        envelope meta, so unsampled tasks cross every hop span-free."""
        # observability config must land in the environment BEFORE the
        # transport forks its broker, so every child role inherits it
        if trace:
            sample = obs.DEFAULT_SAMPLE if trace is True else float(trace)
            trace_dir = (trace_dir or os.environ.get(obs.ENV_DIR)
                         or tempfile.mkdtemp(prefix="repro-obs-"))
            os.environ[obs.ENV_DIR] = trace_dir
            os.environ[obs.ENV_SAMPLE] = repr(sample)
        elif trace is not None:
            os.environ.pop(obs.ENV_DIR, None)     # explicit off
        self.trace_dir = os.environ.get(obs.ENV_DIR, "")
        if self.trace_dir:
            obs.configure(role="thinker")
        if transport is not None and snapshot_every:
            raise ValueError(
                "snapshot_every configures the broker the queues fork:"
                " with an explicit transport, auto-snapshot is configured"
                " where its broker is launched (ProcTransport/ClusterSpec"
                " snapshot_every)")
        if transport is None:
            kw = {} if lease_timeout is None \
                else {"lease_timeout": lease_timeout}
            if snapshot_every:
                if backend != "proc":
                    raise ValueError(
                        "snapshot_every is broker-side crash protection:"
                        " it requires backend='proc'")
                kw.update(snapshot_every=snapshot_every,
                          snapshot_path=snapshot_path)
            transport = make_transport(backend, **kw)
        self.transport = transport
        self.backend = self.transport.name
        self._topics = {t: TopicQueue(self.transport, t) for t in topics}
        self.serve_spec = serve_spec
        if serve_spec is not None and serve_spec.topic not in self._topics:
            self._topics[serve_spec.topic] = TopicQueue(self.transport,
                                                        serve_spec.topic)
        self.value_server = value_server
        self.proxy_threshold = proxy_threshold
        self.release_inputs = release_inputs
        self._active = 0
        self._lock = threading.Lock()
        self._all_done = threading.Condition(self._lock)

    @classmethod
    def connect(cls, topics: Iterable[str], address: tuple, *,
                lease_timeout: Optional[float] = None,
                **kwargs) -> "ColmenaQueues":
        """Cluster-aware construction: attach to an existing broker --
        a plain remote ``ProcTransport`` fabric or a federation member
        bound by ``ClusterLauncher`` (``launcher.address_of(host)``).
        Every queue/checkpoint/resume semantic is identical; topics
        homed at other federation members are simply one relay hop
        away."""
        from repro.core.transport.proc import ProcTransport
        kw = {} if lease_timeout is None else {"lease_timeout": lease_timeout}
        return cls(topics, transport=ProcTransport(address=address, **kw),
                   **kwargs)

    def topics(self):
        """Worker-pool topics.  The serve topic is excluded: it is
        drained by inference shards, and a Task Server intake on it
        would steal requests the shards are supposed to micro-batch."""
        skip = None if self.serve_spec is None else self.serve_spec.topic
        return [t for t in self._topics if t != skip]

    def wake_all(self) -> None:
        """Wake every blocked consumer (used on shutdown/done events)."""
        self.transport.wake_all()
        with self._lock:
            self._all_done.notify_all()

    def shutdown(self) -> None:
        """Tear down transport-owned processes (broker).  A no-op for the
        local backend; idempotent."""
        self.wake_all()
        self.transport.close()
        if self.trace_dir:
            # this process's buffered span tail (submit/decode spans,
            # local-backend broker spans) must be on disk before any
            # same-process report reads the sinks
            obs.flush()

    # -- checkpoint / resume ------------------------------------------------

    def checkpoint(self, path: str, extra=None) -> str:
        """Write a resumable image of the fabric to ``path``: the
        transport snapshot (queued + in-flight envelopes, leases, claim
        window) plus the active-task count, and any picklable ``extra``
        the application wants to travel with it (Thinker progress, a
        CampaignRecord).  Written atomically (tmp + rename) so a kill
        mid-checkpoint leaves the previous checkpoint intact.

        The transport snapshot is a consistent cut of the queues, but
        the active count and the application's ``extra`` are read
        separately: call from the (sole) result-consuming thread with no
        concurrent ``send_task`` -- the blessed site is
        ``BaseThinker.after_result_batch``, where every result of the
        drained (already-acked) batch has been counted -- so the
        progress written cannot drift from the captured queues.  A count
        that includes a task the snapshot missed would make a resumed
        ``wait_until_done`` wait forever.

        Value Server contents travel WITH the checkpoint: a snapshot of
        the attached server (both storage tiers, deduplicated across
        replicas) is bundled so restored task/result proxies resolve in
        the next incarnation -- proxied payloads no longer have to be
        carried inline to be checkpointable."""
        # transport BEFORE value server: a payload is always put before
        # the envelope referencing it, so any proxy inside a captured
        # envelope was stored before the transport cut -- and therefore
        # before the (later) VS snapshot.  The reverse order could image
        # a result envelope whose payload missed the VS cut: a dangling
        # proxy on a *claimed* task id, which is an unrecoverable lost
        # task.
        #
        # The residual window -- a worker completing between the two
        # cuts, whose one-shot input release beats the VS snapshot while
        # the transport cut still images its request as in-flight -- is
        # closed by verification: every completion fuses a claim into
        # the result put *before* the release, so if a transport re-cut
        # taken after the VS snapshot shows the same claim window, no
        # release can have raced the VS cut and the pair is consistent.
        # On mismatch both cuts are retaken (the completed task's claim
        # and result envelope are then inside the transport cut, and its
        # released inputs are no longer needed).  If the fabric outruns
        # every retry, the stale pair still errors a redelivered
        # re-execution out visibly -- never silently losing work.
        transport_snap = self.transport.snapshot()
        vs = None
        if self.value_server is not None \
                and hasattr(self.value_server, "snapshot"):
            baseline = self._claim_ids(transport_snap)
            for _ in range(5):
                vs = self.value_server.snapshot()
                recut = self.transport.snapshot()
                ids = self._claim_ids(recut)
                if ids == baseline:
                    break
                transport_snap, baseline = recut, ids
        payload = {"version": 1,
                   "transport": transport_snap,
                   "active": self.active_count,
                   "vs": vs,
                   "extra": extra}
        tmp = path + ".tmp"
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(tmp, "wb") as f:
            pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
        return path

    @staticmethod
    def _claim_ids(snap: bytes) -> set:
        """The union of claim-window ids inside a transport snapshot --
        single broker or federation bundle.  Every task completion fuses
        a claim into its result put, so two cuts with equal claim sets
        bracket an interval in which no task completed (the
        ``checkpoint`` consistency check)."""
        from repro.core.transport.base import load_snapshot
        payload = pickle.loads(snap)
        if isinstance(payload, dict) and "fed_snapshot" in payload:
            states = [load_snapshot(b) for b in payload["hosts"].values()]
        else:
            states = [load_snapshot(snap)]
        ids: set = set()
        for state in states:
            ids.update(state["claims"]["order"])
        return ids

    @staticmethod
    def load_checkpoint(path: str) -> dict:
        """Read + validate a checkpoint file without restoring it, e.g.
        to inspect ``extra`` before constructing the fabric it
        configures.  Pass the returned payload to ``resume`` to avoid a
        second read of the (potentially large) snapshot blob.

        Accepts two formats: an application checkpoint written by
        ``checkpoint`` (transport snapshot + active count + extra), or a
        **raw broker auto-snapshot** (single broker or a federation
        bundle) written by the broker's ``snapshot_every`` timer.  A raw
        snapshot has no application around to record the active count,
        so it is *derived* from the captured envelopes and claim window
        (``transport.base.derive_active``: ids whose completion was
        already claimed-and-consumed are excluded, or a resumed
        ``wait_until_done`` would wait on them forever) -- and ``extra``
        is None (broker-side snapshots cannot capture Thinker progress;
        applications that need ``extra`` keep calling ``checkpoint``)."""
        from repro.core.transport.base import derive_active, load_snapshot
        with open(path, "rb") as f:
            raw = f.read()
        payload = pickle.loads(raw)
        if isinstance(payload, dict) and "transport" in payload:
            if payload.get("version") != 1:
                raise ValueError("unsupported checkpoint version "
                                 f"{payload.get('version')!r}")
            return payload
        if isinstance(payload, dict) and "fed_snapshot" in payload:
            active = derive_active([load_snapshot(s)
                                    for s in payload["hosts"].values()])
            return {"version": 1, "transport": raw, "active": active,
                    "extra": None}
        if isinstance(payload, dict) and "queues" in payload:
            return {"version": 1, "transport": raw,
                    "active": derive_active([load_snapshot(raw)]),
                    "extra": None}
        raise ValueError(f"{path}: neither a checkpoint nor a broker "
                         "snapshot")

    def resume(self, path: str, payload: Optional[dict] = None):
        """Restore a ``checkpoint`` into this (fresh) fabric and return
        the ``extra`` that was stored with it.  Queued tasks re-dispatch,
        in-flight leases expire and redeliver, completed-but-unconsumed
        results deliver from the restored result queues, and the restored
        claim window swallows re-executions of work that already
        published -- so nothing is lost and nothing completes twice.
        Call before task servers / Thinker agents start consuming.

        The end-to-end guarantee needs every in-flight task to live in
        transport state, which is true of ``ProcessPoolTaskServer`` on
        the ``proc`` backend (workers hold their dispatch leases for the
        whole execution).  The in-process thread ``TaskServer`` hands
        tasks to its executor after acking them, so a checkpoint taken
        while it runs captures only still-queued work -- quiesce it
        first, or use the process pool for resumable campaigns."""
        if payload is None:
            payload = self.load_checkpoint(path)
        vs_blob = payload.get("vs")
        if vs_blob is not None:
            if self.value_server is None:
                raise ValueError(
                    "checkpoint bundles Value Server contents but this "
                    "fabric has no value_server attached: restored "
                    "proxies would dangle")
            # restore payloads BEFORE queue state: once the transport is
            # live a consumer could lease a restored task and resolve its
            # proxies immediately
            self.value_server.restore(vs_blob)
        # the checkpointed incarnation is dead: requeue its in-flight
        # leases immediately instead of waiting out their durations
        self.transport.restore(payload["transport"], expire_leases=True)
        with self._lock:
            self._active = payload["active"]
        return payload["extra"]

    # -- Thinker side -------------------------------------------------------

    def send_task(self, *args, method: str, topic: str = "default",
                  **kwargs) -> str:
        task = msg.Task(topic=topic, method=method, args=args, kwargs=kwargs)
        task.timer.mark("created")
        if self.value_server is not None and self.proxy_threshold is not None:
            task.args = proxy_tree(task.args, self.value_server,
                                   self.proxy_threshold, task.timer,
                                   one_shot=True)
            task.kwargs = proxy_tree(task.kwargs, self.value_server,
                                     self.proxy_threshold, task.timer,
                                     one_shot=True)
        data = msg.timed_serialize(task, task.timer, "serialize_request")
        t_ser = now()
        # single serialization: the measured time/size ride in the envelope
        # (proxy_put was recorded before pickling, so it already travels
        # inside the payload; only post-pickle measurements ride in meta).
        # Timer measurements live in the namespaced "timers" sub-dict;
        # top-level meta is bookkeeping (task_id so a relaying task
        # server can track in-flight work without unpickling the
        # payload, sizes, placement, the trace flag)
        meta = {"timers": {"serialize_request":
                           task.timer.intervals["serialize_request"]},
                "input_size": len(data), "task_id": task.task_id}
        traced = bool(self.trace_dir) and obs.sampled(task.task_id)
        if traced:
            meta["trace"] = 1
        with self._lock:
            self._active += 1
        self._topics[task.topic].requests.put(Envelope(now(), data, meta))
        if traced:
            dur = task.timer.intervals["serialize_request"]
            obs.span(task.task_id, "serialize_request", t_ser - dur, t_ser)
            obs.span(task.task_id, "submit", t_ser - dur, now(),
                     topic=task.topic)
        return task.task_id

    @property
    def serve_topic(self) -> str:
        if self.serve_spec is None:
            raise ValueError(
                "no serve_spec declared: pass serve_spec= to ColmenaQueues"
                " (or an explicit topic= to send_inference)")
        return self.serve_spec.topic

    def send_inference(self, tokens, *, max_new: Optional[int] = None,
                       topic: Optional[str] = None) -> str:
        """Enqueue one inference request (a token-id prompt) on the
        serve topic and return its task id.  The draining inference
        shard buckets it by prompt length into a pad-bounded micro-batch
        with whatever else is queued -- possibly other clients' traffic
        -- and streams the generated ids back as an ordinary ``Result``
        on the topic's result queue (``value`` = generated token list).
        ``serving.shard.InferenceClient`` wraps this with transparent
        split/reassemble over many prompts.  Exactly-once, lease
        redelivery, and checkpoint/resume apply exactly as for
        ``send_task``: this *is* a task, just served by a shard instead
        of a worker pool."""
        return self.send_task(method="infer",
                              topic=topic or self.serve_topic,
                              tokens=[int(t) for t in tokens],
                              max_new=max_new)

    def _decode_result(self, env: Envelope) -> msg.Result:
        result: msg.Result = msg.deserialize(env.data)
        # sender-side Timer measurements ride the namespaced "timers"
        # sub-dict; every other meta key is bookkeeping by construction,
        # so a new top-level key can never be misrecorded as a lifecycle
        # interval (the PR-4/PR-8 grafting-bug class, closed structurally)
        for name, seconds in env.meta.get("timers", {}).items():
            result.timer.record(name, seconds)
        if "output_size" in env.meta:
            result.output_size = env.meta["output_size"]
        t_recv = now()
        result.timer.record("result_queue_transit", t_recv - env.t_put)
        traced = bool(env.meta.get("trace"))
        attempt = int(env.meta.get("redelivered", 0) or 0)
        if traced:
            obs.span(result.task_id, "result_queue_transit", env.t_put,
                     t_recv, attempt=attempt)
        # note the one-shot proxies before resolution replaces them in-tree
        one_shot = ([p for p in iter_proxies(result.value) if p.one_shot]
                    if self.value_server is not None else [])
        t0 = now()
        result.value = resolve_tree(result.value, self.value_server)
        t1 = now()
        result.timer.record("deserialize_result", t1 - t0)
        if traced:
            obs.span(result.task_id, "deserialize_result", t0, t1,
                     attempt=attempt)
            # the envelope Timer's final totals, for the report's
            # decomposition acceptance check
            obs.emit_timers(result.task_id, result.timer.intervals)
        for p in one_shot:
            # result payloads have exactly one consumer: release immediately
            self.value_server.release(p.key)
        with self._lock:
            self._active -= 1
            if self._active <= 0:
                self._all_done.notify_all()
        return result

    def get_result(self, topic: str = "default",
                   timeout: Optional[float] = None,
                   cancel: Optional[threading.Event] = None
                   ) -> Optional[msg.Result]:
        env = self._topics[topic].results.get(timeout=timeout, cancel=cancel)
        if env is None:
            return None
        result = self._decode_result(env)
        # decoded and about to be handed to the caller: commit the lease
        # NOW (flush, not piggyback) -- a consumer that processes this
        # result for longer than lease_timeout before sending its next
        # frame must not get it redelivered
        self._topics[topic].results.ack(flush=True)
        return result

    def get_results(self, topic: str = "default", max_n: int = 32,
                    timeout: Optional[float] = None,
                    cancel: Optional[threading.Event] = None
                    ) -> List[msg.Result]:
        """Blocking batched drain, mirroring ``get_tasks``: one wakeup can
        hand a result-processor thread up to ``max_n`` completed results
        (empty list = cancelled/timed out)."""
        envs = self._topics[topic].results.get_batch(max_n, timeout=timeout,
                                                     cancel=cancel)
        results = [self._decode_result(e) for e in envs]
        if envs:
            # flush: the batch may take arbitrarily long to process
            self._topics[topic].results.ack(flush=True)
        return results

    def cancel(self, task_id: str, topic: str = "default") -> bool:
        """Preempt a task: the broker-side ``cancel`` op claims the id
        (so a racing completion dedups through the same fused put-claim
        path -- exactly one of cancel/complete wins), destroys every
        queued copy (original, retry requeue, straggler backup clone),
        revokes in-flight leases, and wakes parked getters so freed
        capacity re-steers immediately.  The executing worker aborts
        cooperatively (next ``report_intermediate``) or via its
        heartbeat probe + SIGTERM escalation (process pool).

        True: this cancel won -- no result will ever arrive for the id,
        and it leaves the active count here.  False: a completion (or an
        earlier cancel) already claimed it -- the result is or will be
        delivered and counts down normally."""
        t0 = now()
        won = self._topics[topic].requests.cancel(task_id)
        if won:
            obs.observe("cancel_latency", now() - t0)
            with self._lock:
                self._active -= 1
                if self._active <= 0:
                    self._all_done.notify_all()
        return won

    def stream_channel(self, topic: str = "default"):
        """The topic's ``stream`` channel (task servers hand it to the
        worker-side ``streaming.TaskContext``)."""
        return self._topics[topic].stream

    def _decode_intermediate(self, env: Envelope) -> msg.Intermediate:
        ob: msg.Intermediate = msg.deserialize(env.data)
        if env.meta.get("trace") and env.meta.get("task_id"):
            obs.span(env.meta["task_id"], "observation_transit", env.t_put,
                     now(), seq=int(env.meta.get("seq", 0)))
        return ob

    def get_intermediates(self, topic: str = "default", max_n: int = 32,
                          timeout: Optional[float] = None,
                          cancel: Optional[threading.Event] = None
                          ) -> List[msg.Intermediate]:
        """Blocking batched drain of the topic's stream lane: one wakeup
        hands back up to ``max_n`` mid-task observations (empty list =
        cancelled/timed out).  Observations are advisory partials --
        they are acked on decode and never claimed, so a redelivered
        duplicate (stream leases expire like any other) is at worst seen
        twice, never lost while the publishing task is still live."""
        envs = self._topics[topic].stream.get_batch(max_n, timeout=timeout,
                                                    cancel=cancel)
        out = [self._decode_intermediate(e) for e in envs]
        if envs:
            self._topics[topic].stream.ack(flush=True)
        return out

    def wait_until_done(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else now() + timeout
        with self._lock:
            while self._active > 0:
                # re-check the predicate: wake_all() notifies unconditionally
                if deadline is None:
                    self._all_done.wait()
                else:
                    remaining = deadline - now()
                    if remaining <= 0:
                        return False
                    self._all_done.wait(remaining)
            return True

    @property
    def active_count(self) -> int:
        with self._lock:
            return self._active

    # -- Task Server side ---------------------------------------------------

    def _decode_task(self, env: Envelope) -> msg.Task:
        task: msg.Task = msg.deserialize(env.data)
        # namespaced "timers" sub-dict only -- top-level bookkeeping
        # (task_id/redelivered/backup/bounces/exclude_*/trace/_shm) can
        # no longer leak into Timer.intervals via a forgotten skip-list
        # entry
        for name, seconds in env.meta.get("timers", {}).items():
            task.timer.record(name, seconds)
        if "input_size" in env.meta:
            task.input_size = env.meta["input_size"]
        t_recv = now()
        task.timer.record("request_queue_transit", t_recv - env.t_put)
        task.timer.mark("received_by_server")
        # delivery-side trace context for the executing role: the
        # sampling verdict and which redelivery attempt this is
        task.trace = bool(env.meta.get("trace"))
        task.attempt = int(env.meta.get("redelivered", 0) or 0)
        if task.trace:
            obs.span(task.task_id, "request_queue_transit", env.t_put,
                     t_recv, attempt=task.attempt, topic=task.topic)
        return task

    def get_task(self, topic: str, timeout: Optional[float] = None,
                 cancel: Optional[threading.Event] = None
                 ) -> Optional[msg.Task]:
        env = self._topics[topic].requests.get(timeout=timeout, cancel=cancel)
        if env is None:
            return None
        task = self._decode_task(env)
        self._topics[topic].requests.ack(flush=True)
        return task

    def get_tasks(self, topic: str, max_n: int = 32,
                  timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[msg.Task]:
        """Blocking batched drain: one wakeup can hand back up to ``max_n``
        queued tasks (empty list = cancelled/timed out)."""
        envs = self._topics[topic].requests.get_batch(max_n, timeout=timeout,
                                                      cancel=cancel)
        tasks = [self._decode_task(e) for e in envs]
        if envs:
            # flush: execution of the drained batch may outlive the lease
            self._topics[topic].requests.ack(flush=True)
        return tasks

    def send_result(self, result: msg.Result, *,
                    claim_id: Optional[str] = None) -> bool:
        """Publish a result.  ``claim_id`` (normally the task id) fuses
        an atomic first-completion claim with the enqueue: only the first
        publisher's result is enqueued (True); raced duplicates -- a
        straggler backup, or a lease-expiry redelivery racing a slow but
        alive original -- are swallowed in the same round trip (False).
        The claim happening *inside* the put leaves no window where an
        id is claimed but its result died with the claimant."""
        if self.value_server is not None and self.proxy_threshold is not None:
            result.value = proxy_tree(result.value, self.value_server,
                                      self.proxy_threshold, result.timer,
                                      prefix="serialize_result",
                                      one_shot=True)
        data = msg.timed_serialize(result, result.timer, "serialize_result")
        t_ser = now()
        # task_id rides the meta (like requests) so a broker auto-snapshot
        # can count a completed-but-unconsumed task as still active;
        # Timer measurements ride the namespaced "timers" sub-dict
        meta = {"timers": {"serialize_result":
                           result.timer.intervals["serialize_result"]},
                "output_size": len(data), "task_id": result.task_id}
        traced = bool(self.trace_dir) and obs.sampled(result.task_id)
        if traced:
            meta["trace"] = 1
        ok = self._topics[result.topic].results.put(
            Envelope(now(), data, meta), claim=claim_id)
        if traced:
            dur = result.timer.intervals["serialize_result"]
            attempt = int(getattr(result, "attempt", 0))
            obs.span(result.task_id, "serialize_result", t_ser - dur,
                     t_ser, attempt=attempt)
            obs.span(result.task_id, "publish_result", t_ser, now(),
                     attempt=attempt, claimed=bool(ok))
        return ok

    def requeue(self, task: msg.Task) -> None:
        """Retry path: put a (deserialized) task back on its request queue."""
        data = msg.serialize(task)
        meta = {"input_size": task.input_size or len(data),
                "task_id": task.task_id}
        # the sampling decision is a deterministic hash of the task id,
        # so a retried task keeps (or keeps lacking) its trace
        if self.trace_dir and obs.sampled(task.task_id):
            meta["trace"] = 1
        self._topics[task.topic].requests.put(Envelope(now(), data, meta))

    def release_task_inputs(self, task: msg.Task) -> None:
        """Drop one-shot input payloads from the Value Server once the task
        reached its final outcome (shared by both task-server flavours so
        the release policy can never drift between them).  Only the race
        *winner* calls this; Thinkers that re-resolve ``result.args`` after
        completion opt out via ``release_inputs=False``."""
        if self.value_server is None or not self.release_inputs:
            return
        for p in iter_proxies(task.args):
            if p.one_shot:
                self.value_server.release(p.key)
        for p in iter_proxies(task.kwargs):
            if p.one_shot:
                self.value_server.release(p.key)
