"""Per-topic request/result queue pairs (the paper's Redis topology).

The Thinker writes Tasks to the request queue of a topic; the Task Server
reads them, executes, and writes Results to the topic's result queue.
Distinct queue pairs per task type simplify multi-agent Thinkers (§III-B3).

Messages physically traverse pickle bytes so the serialization /
communication costs the paper measures are real, not simulated.  Each
message is serialized **exactly once** per queue hop: the pickled payload
travels inside a tiny envelope that carries the enqueue timestamp plus the
serialization time / payload size measured from those same bytes, and the
receiver grafts them onto the deserialized message's Timer.

*Where* the envelope waits is a pluggable transport backend
(``repro.core.transport``):

- ``backend="local"`` -- in-process ``Condition``-notified deques:
  consumers block until a producer notifies them, ``wake_all()`` nudges
  every blocked consumer so shutdown events propagate immediately, and
  batched drains (``get_tasks`` / ``get_results``) amortize wakeups.
- ``backend="proc"`` -- the envelope's single-pickle bytes become a
  socket frame to a broker process, so Thinker and Task Server can be
  different OS processes (the paper's multi-process topology) with the
  exact same call-site API and the same blocking/batching semantics.

A configurable proxy threshold transparently moves large values through the
Value Server instead (lazy object proxies); those one-shot entries are
refcounted and released once their single consumer resolves them.
"""
from __future__ import annotations

import threading
from typing import Iterable, List, Optional

from repro.core import message as msg
from repro.core.transport import Envelope, Transport, make_transport
from repro.core.value_server import iter_proxies, proxy_tree, resolve_tree
from repro.utils.timing import now


class TopicQueue:
    def __init__(self, transport: Transport, topic: str):
        self.requests = transport.channel(topic, "requests")
        self.results = transport.channel(topic, "results")


class ColmenaQueues:
    """The Thinker <-> Task Server communication fabric."""

    def __init__(self, topics: Iterable[str], *,
                 backend: str = "local",
                 transport: Optional[Transport] = None,
                 value_server=None,
                 proxy_threshold: Optional[int] = None,
                 release_inputs: bool = True):
        """backend: "local" (in-process deques) or "proc" (socket broker
        process); ignored when an explicit ``transport`` is given.
        release_inputs: delete one-shot proxied task inputs from the
        Value Server once the task completes (bounds campaign memory).
        Set False if your Thinker resolves ``result.args`` proxies after
        completion, e.g. to resubmit the exact input payload."""
        self.transport = transport if transport is not None \
            else make_transport(backend)
        self.backend = self.transport.name
        self._topics = {t: TopicQueue(self.transport, t) for t in topics}
        self.value_server = value_server
        self.proxy_threshold = proxy_threshold
        self.release_inputs = release_inputs
        self._active = 0
        self._lock = threading.Lock()
        self._all_done = threading.Condition(self._lock)

    def topics(self):
        return list(self._topics)

    def wake_all(self) -> None:
        """Wake every blocked consumer (used on shutdown/done events)."""
        self.transport.wake_all()
        with self._lock:
            self._all_done.notify_all()

    def shutdown(self) -> None:
        """Tear down transport-owned processes (broker).  A no-op for the
        local backend; idempotent."""
        self.wake_all()
        self.transport.close()

    # -- Thinker side -------------------------------------------------------

    def send_task(self, *args, method: str, topic: str = "default",
                  **kwargs) -> str:
        task = msg.Task(topic=topic, method=method, args=args, kwargs=kwargs)
        task.timer.mark("created")
        if self.value_server is not None and self.proxy_threshold is not None:
            task.args = proxy_tree(task.args, self.value_server,
                                   self.proxy_threshold, task.timer,
                                   one_shot=True)
            task.kwargs = proxy_tree(task.kwargs, self.value_server,
                                     self.proxy_threshold, task.timer,
                                     one_shot=True)
        data = msg.timed_serialize(task, task.timer, "serialize_request")
        # single serialization: the measured time/size ride in the envelope
        # (proxy_put was recorded before pickling, so it already travels
        # inside the payload; only post-pickle measurements ride in meta)
        # task_id rides the meta so a relaying task server can track
        # in-flight work without unpickling the payload
        meta = {"serialize_request": task.timer.intervals["serialize_request"],
                "input_size": len(data), "task_id": task.task_id}
        with self._lock:
            self._active += 1
        self._topics[task.topic].requests.put(Envelope(now(), data, meta))
        return task.task_id

    def _decode_result(self, env: Envelope) -> msg.Result:
        result: msg.Result = msg.deserialize(env.data)
        for name, seconds in env.meta.items():
            if name == "output_size":
                result.output_size = seconds
            else:
                result.timer.record(name, seconds)
        result.timer.record("result_queue_transit", now() - env.t_put)
        # note the one-shot proxies before resolution replaces them in-tree
        one_shot = ([p for p in iter_proxies(result.value) if p.one_shot]
                    if self.value_server is not None else [])
        t0 = now()
        result.value = resolve_tree(result.value, self.value_server)
        result.timer.record("deserialize_result", now() - t0)
        for p in one_shot:
            # result payloads have exactly one consumer: release immediately
            self.value_server.release(p.key)
        with self._lock:
            self._active -= 1
            if self._active <= 0:
                self._all_done.notify_all()
        return result

    def get_result(self, topic: str = "default",
                   timeout: Optional[float] = None,
                   cancel: Optional[threading.Event] = None
                   ) -> Optional[msg.Result]:
        env = self._topics[topic].results.get(timeout=timeout, cancel=cancel)
        if env is None:
            return None
        return self._decode_result(env)

    def get_results(self, topic: str = "default", max_n: int = 32,
                    timeout: Optional[float] = None,
                    cancel: Optional[threading.Event] = None
                    ) -> List[msg.Result]:
        """Blocking batched drain, mirroring ``get_tasks``: one wakeup can
        hand a result-processor thread up to ``max_n`` completed results
        (empty list = cancelled/timed out)."""
        envs = self._topics[topic].results.get_batch(max_n, timeout=timeout,
                                                     cancel=cancel)
        return [self._decode_result(e) for e in envs]

    def wait_until_done(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else now() + timeout
        with self._lock:
            while self._active > 0:
                # re-check the predicate: wake_all() notifies unconditionally
                if deadline is None:
                    self._all_done.wait()
                else:
                    remaining = deadline - now()
                    if remaining <= 0:
                        return False
                    self._all_done.wait(remaining)
            return True

    @property
    def active_count(self) -> int:
        with self._lock:
            return self._active

    # -- Task Server side ---------------------------------------------------

    def _decode_task(self, env: Envelope) -> msg.Task:
        task: msg.Task = msg.deserialize(env.data)
        for name, seconds in env.meta.items():
            if name == "input_size":
                task.input_size = seconds
            elif name == "task_id":
                pass                        # bookkeeping, not a timer
            else:
                task.timer.record(name, seconds)
        task.timer.record("request_queue_transit", now() - env.t_put)
        task.timer.mark("received_by_server")
        return task

    def get_task(self, topic: str, timeout: Optional[float] = None,
                 cancel: Optional[threading.Event] = None
                 ) -> Optional[msg.Task]:
        env = self._topics[topic].requests.get(timeout=timeout, cancel=cancel)
        if env is None:
            return None
        return self._decode_task(env)

    def get_tasks(self, topic: str, max_n: int = 32,
                  timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[msg.Task]:
        """Blocking batched drain: one wakeup can hand back up to ``max_n``
        queued tasks (empty list = cancelled/timed out)."""
        envs = self._topics[topic].requests.get_batch(max_n, timeout=timeout,
                                                      cancel=cancel)
        return [self._decode_task(e) for e in envs]

    def send_result(self, result: msg.Result) -> None:
        if self.value_server is not None and self.proxy_threshold is not None:
            result.value = proxy_tree(result.value, self.value_server,
                                      self.proxy_threshold, result.timer,
                                      prefix="serialize_result",
                                      one_shot=True)
        data = msg.timed_serialize(result, result.timer, "serialize_result")
        meta = {"serialize_result": result.timer.intervals["serialize_result"],
                "output_size": len(data)}
        self._topics[result.topic].results.put(Envelope(now(), data, meta))

    def requeue(self, task: msg.Task) -> None:
        """Retry path: put a (deserialized) task back on its request queue."""
        data = msg.serialize(task)
        meta = {"input_size": task.input_size or len(data),
                "task_id": task.task_id}
        self._topics[task.topic].requests.put(Envelope(now(), data, meta))

    def release_task_inputs(self, task: msg.Task) -> None:
        """Drop one-shot input payloads from the Value Server once the task
        reached its final outcome (shared by both task-server flavours so
        the release policy can never drift between them).  Only the race
        *winner* calls this; Thinkers that re-resolve ``result.args`` after
        completion opt out via ``release_inputs=False``."""
        if self.value_server is None or not self.release_inputs:
            return
        for p in iter_proxies(task.args):
            if p.one_shot:
                self.value_server.release(p.key)
        for p in iter_proxies(task.kwargs):
            if p.one_shot:
                self.value_server.release(p.key)
