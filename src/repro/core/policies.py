"""Task-selection policies for steering campaigns.

The paper's application uses Upper Confidence Bound over an MPNN ensemble;
we provide that plus the baselines (random, greedy) the paper compares in
Fig. 4, and generic batch selectors.
"""
from __future__ import annotations

import numpy as np


def ucb_scores(preds: np.ndarray, kappa: float = 2.0) -> np.ndarray:
    """preds (E, N) ensemble predictions -> UCB per candidate."""
    return preds.mean(axis=0) + kappa * preds.std(axis=0)


def greedy_scores(preds: np.ndarray) -> np.ndarray:
    return preds.mean(axis=0)


def random_scores(n: int, rng: np.random.Generator) -> np.ndarray:
    return rng.random(n)


def select_batch(scores: np.ndarray, k: int, exclude=()) -> list:
    """Top-k candidate indices by score, skipping `exclude`."""
    order = np.argsort(-scores)
    out = []
    excl = set(exclude)
    for i in order:
        if int(i) not in excl:
            out.append(int(i))
            if len(out) >= k:
                break
    return out


def epsilon_greedy(scores: np.ndarray, k: int, eps: float,
                   rng: np.random.Generator, exclude=()) -> list:
    """Mix of exploitation and uniform exploration."""
    n_rand = int(round(eps * k))
    top = select_batch(scores, k - n_rand, exclude)
    pool = [i for i in range(len(scores))
            if i not in set(exclude) and i not in set(top)]
    rand = list(rng.choice(pool, size=min(n_rand, len(pool)),
                           replace=False)) if pool and n_rand else []
    return top + [int(i) for i in rand]
