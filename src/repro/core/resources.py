"""ResourceTracker: pooled resource accounting + reallocation (§III-B1).

Stores a fixed number of resource slots partitioned into named pools
(e.g. "simulation", "inference", "training").  Agent threads acquire and
release slots concurrently; an Allocator agent moves slots between pools
("different colored traffic lights" in the paper's Fig. 2).  Reallocation
of *busy* slots is deferred: the slots transfer as they are released.

On the TPU adaptation a slot is a mesh slice (DESIGN.md §2); the quantum of
reallocation is the largest slice a task type needs, exactly as the paper
reallocates Theta nodes in 4-node increments.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional


class ResourceTracker:
    def __init__(self, pools: Dict[str, int]):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._total = dict(pools)          # slots assigned to each pool
        self._in_use = {p: 0 for p in pools}
        self._pending_moves: list = []     # deferred (src, dst, n)

    # -- queries -------------------------------------------------------------

    def available(self, pool: str) -> int:
        with self._lock:
            return self._total[pool] - self._in_use[pool]

    def allocation(self, pool: str) -> int:
        with self._lock:
            return self._total[pool]

    def utilization(self) -> Dict[str, tuple]:
        with self._lock:
            return {p: (self._in_use[p], self._total[p]) for p in self._total}

    # -- acquire/release -------------------------------------------------------

    def acquire(self, pool: str, n: int = 1,
                timeout: Optional[float] = None) -> bool:
        deadline = None
        if timeout is not None:
            import time
            deadline = time.monotonic() + timeout
        with self._cv:
            while self._total[pool] - self._in_use[pool] < n:
                if deadline is None:
                    self._cv.wait()
                else:
                    import time
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or not self._cv.wait(remaining):
                        if self._total[pool] - self._in_use[pool] >= n:
                            break
                        return False
            self._in_use[pool] += n
            return True

    def release(self, pool: str, n: int = 1) -> None:
        with self._cv:
            self._in_use[pool] -= n
            assert self._in_use[pool] >= 0, (pool, self._in_use[pool])
            self._apply_pending_locked()
            self._cv.notify_all()

    # -- reallocation ----------------------------------------------------------

    def reallocate(self, src: str, dst: str, n: int,
                   block: bool = False) -> int:
        """Move up to n slots src -> dst.  Free slots move immediately; busy
        slots move as they are released (deferred).  Returns slots moved
        immediately."""
        with self._cv:
            free = self._total[src] - self._in_use[src]
            move_now = min(free, n)
            self._total[src] -= move_now
            self._total[dst] += move_now
            deferred = n - move_now
            if deferred > 0:
                self._pending_moves.append([src, dst, deferred])
            self._cv.notify_all()
            if block:
                while any(m[2] > 0 for m in self._pending_moves):
                    self._cv.wait()
            return move_now

    def _apply_pending_locked(self) -> None:
        for move in self._pending_moves:
            src, dst, want = move
            free = self._total[src] - self._in_use[src]
            take = min(free, want)
            if take > 0:
                self._total[src] -= take
                self._total[dst] += take
                move[2] -= take
        self._pending_moves = [m for m in self._pending_moves if m[2] > 0]
