"""ProcessPoolTaskServer: registered methods execute in worker OS processes.

The thread-pool ``TaskServer`` gives concurrency; this one gives the
paper's topology -- N *processes* per topic (Parsl workers), true
parallelism for CPU-bound simulation tasks, and per-worker **identity**
(``host/topic/wR/pidP``) so placement decisions are possible.  It requires
the ``proc`` queue backend: the parent (supervisor) and the workers only
ever meet through the broker.

Direct-subscription data plane (no relay in the dispatch path)::

    Thinker --put--> topic requests --get--> worker executes --put--> results
                          ^                     |
                          |  control events     v
                     supervisor  <---- pool@<host>:__control__

Workers subscribe **directly** to the topic's request queue at its home
broker: each worker's leased ``get`` *is* the dispatch, and the lease it
holds across the execution *is* the in-flight record.  The pool parent
never touches an envelope -- it is a pure control-plane supervisor that
watches ``started``/``retry``/``done`` events on a per-host control
channel, keeps runtime history, and schedules straggler backups.  (The
previous design relayed every envelope through a parent intake thread
onto a per-host dispatch queue: one extra broker round-trip per task,
and the parent held a copy of every in-flight payload.)

Straggler mitigation with *placement*: when a task exceeds
``straggler_factor`` x the topic's trailing-median runtime, the
supervisor asks the broker to **clone the leased envelope** back onto
the queue (``Channel.backup`` -- the broker's lease ledger is the only
place the bytes still live), with ``exclude_worker`` (and, when peer
hosts pool the topic, ``exclude_host``) merged into the clone's meta.
An excluded worker that picks the clone up bounces it -- re-puts the
bytes verbatim with a bumped ``bounces`` count and acks, no unpickle --
so an idle *different* worker (on a different host when one exists)
executes the backup.  First completion wins: workers arbitrate via the
claim fused into the result ``put``, so exactly one result per task id
reaches the Thinker even though the racers live in different processes.

Topology awareness: every pool carries a **host identity** (``host=``;
defaults to the real hostname) that prefixes each worker identity and
scopes the pool's control channel (``pool@<host>:__control__``), so each
supervisor monitors exactly its own workers.  ``backup_hosts`` names
peer hosts running pools for the same topics: a straggler backup then
excludes the *whole origin host* (surviving a host-wide slowdown, not
just a slow process -- the paper's Theta runs), falling back to
same-host ``exclude_worker`` bouncing when no peer exists.

Long tasks and leases: each worker runs a heartbeat thread that renews
the request-queue lease at half its timeout while a task executes, so
work that legitimately outlives ``lease_timeout`` keeps its lease
instead of triggering a wasteful redelivery that the claim then has to
dedup.  A SIGKILLed worker stops heartbeating, its lease expires at the
home broker, and the task redelivers to any subscribed worker -- on any
host -- with no supervisor involvement.

Shutdown is a SIGTERM protocol (there are no stop envelopes: a stop
riding a queue shared by every host's workers could land anywhere).  An
idle worker's SIGTERM handler exits the process right there -- the
interrupted blocking ``recv`` would otherwise just resume (PEP 475); a
busy worker finishes its task, observes the flag, flushes and exits.

Fault tolerance mirrors the thread server -- per-task retry with capped
attempts, errors captured into the Result, one-shot Value-Server inputs
released by the winning worker only -- and adds **exactly-once dispatch**
on top of the transport's leases: a worker holds its request-queue
lease for the task's whole execution and only acks after the result is
published, so a worker SIGKILLed mid-task (or a response frame lost with
its connection) leaves an unacked lease that expires and redelivers the
task to a *different* worker.  Completions arbitrate via the claim fused
into the result ``put``, so a redelivery racing a slow-but-alive
original -- like a straggler backup racing its original -- yields exactly
one result per task id.

Workers are **forked** (not spawned): registered methods may be closures
or lambdas, which only fork can inherit.  CPython >= 3.12 warns about
forking a multi-threaded process; the children here never touch the
parent's thread state -- they immediately enter the dispatch loop and
only run stdlib/pickle/numpy plus the registered method -- and every
socket client reconnects per-pid, so the warning is benign for this
usage.  Fork workers *before* starting Thinker agent threads (the
``with pool:`` idiom does this naturally).
"""
from __future__ import annotations

import os
import pickle
import signal
import socket as socketlib
import threading
import time
import traceback
from typing import Callable, Dict, Optional

from repro import observability as obs
from repro.core import message as msg
from repro.core import streaming
from repro.core.queues import ColmenaQueues
from repro.core.task_server import MethodSpec
from repro.core.transport import Envelope
from repro.core.transport.base import BoundedDict
from repro.core.value_server import ValueServer, resolve_tree
from repro.utils.timing import now

_MAX_BOUNCES = 16       # prefer progress over placement after this many

POOL_PREFIX = "pool@"


def dispatch_topic(host: str, topic: str) -> str:
    """The per-host pool channel name for ``topic``.  The direct data
    plane no longer dispatches through these (workers drain the global
    topic queue at its home broker), but the naming -- and
    ``cluster.spec.resolve_home``'s rule homing ``pool@<host>:`` topics
    at that host's broker -- remains for the control channel below and
    for anything host-scoped a deployment wants kept on-host."""
    return f"{POOL_PREFIX}{host}:{topic}"


def control_topic(host: str) -> str:
    """Per-host pool control channel: each supervisor monitors only its
    own workers' events (a shared control topic across hosts would race
    on leases and split events randomly between monitors)."""
    return f"{POOL_PREFIX}{host}:__control__"


def host_of(identity: str) -> str:
    """The host component of a worker identity (``host/topic/wR/pidP``)."""
    return identity.split("/", 1)[0]


class ProcessPoolTaskServer:
    def __init__(self, queues: ColmenaQueues, *, workers_per_topic=2,
                 straggler_factor: Optional[float] = None,
                 straggler_min_history: int = 5, intake_batch: int = 32,
                 history_window: int = 4096,
                 host: Optional[str] = None,
                 backup_hosts: Optional[list] = None):
        """workers_per_topic: an int (uniform) or a {topic: n} dict (a
        cluster host runs only the pools its HostSpec lists, with
        per-topic sizes).  host: this pool's host identity; None uses
        the real hostname.  Simulated hosts sharing one machine pass
        distinct names so placement decisions stay meaningful.
        intake_batch: control-event drain batch size (the name predates
        the direct data plane, when it also sized the intake relay).
        backup_hosts: peer hosts running pools for the same topics --
        a straggler backup excludes the origin host when one exists.
        Either a flat list (every topic) or a {topic: [hosts]} dict (an
        exclusion must only be total when *some* other host pools the
        topic, or the backup would bounce forever)."""
        if queues.backend != "proc":
            raise ValueError(
                "ProcessPoolTaskServer requires ColmenaQueues(backend='proc')"
                " -- worker processes can only reach a socket-backed fabric")
        if isinstance(queues.value_server, ValueServer):
            raise ValueError(
                "an in-process ValueServer is invisible to worker processes;"
                " use transport.shards.ShardedValueServer (or None)")
        self.queues = queues
        self.straggler_factor = straggler_factor
        self.straggler_min_history = straggler_min_history
        self.intake_batch = intake_batch
        self._workers_per_topic = workers_per_topic
        self.host = host or socketlib.gethostname()
        self.backup_hosts = backup_hosts or []
        self._backup_rr = 0                    # round-robin over peers
        self.backup_targets: Dict[str, str] = {}  # task_id -> backup host
        self._methods: Dict[str, MethodSpec] = {}
        self._procs: list = []
        self._threads: list = []
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._straggler_cond = threading.Condition(self._lock)
        self._inflight: Dict[str, dict] = {}   # task_id -> info
        self._runtimes: Dict[str, list] = {}   # topic -> recent runtimes
        # task_id -> [identities that *started* it], for tests/diagnostics;
        # sliding-window bounded (BoundedIdSet's eviction pattern) so the
        # map cannot grow without limit over a long campaign
        self.task_history = BoundedDict(history_window)

    # -- registration ---------------------------------------------------------

    def register(self, fn: Callable, *, topic: Optional[str] = None,
                 name: Optional[str] = None, max_retries: int = 1):
        name = name or fn.__name__
        topic = topic or name
        self._methods[name] = MethodSpec(fn, topic=topic,
                                         max_retries=max_retries)
        return name

    # -- channels -------------------------------------------------------------

    def _request_channel(self, topic: str):
        """The global request queue workers subscribe to -- the same
        channel the Thinker publishes into, reached directly at its home
        broker (``ProcTransport.client_for``)."""
        return self.queues.transport.channel(topic, "requests")

    def _control_channel(self):
        return self.queues.transport.channel(control_topic(self.host),
                                             "events")

    def _n_workers(self, topic: str) -> int:
        if isinstance(self._workers_per_topic, dict):
            return self._workers_per_topic.get(topic, 0)
        return self._workers_per_topic

    # -- lifecycle ------------------------------------------------------------

    def start(self):
        import multiprocessing
        ctx = multiprocessing.get_context("fork")
        topics = self.queues.topics()
        for topic in topics:
            if self._n_workers(topic) == 0:
                continue                    # this host does not pool it
            for rank in range(self._n_workers(topic)):
                p = ctx.Process(target=self._worker_main, args=(topic, rank),
                                daemon=True, name=f"pool-{topic}-w{rank}")
                p.start()
                self._procs.append(p)
        th = threading.Thread(target=self._monitor_loop, daemon=True,
                              name="pool-monitor")
        th.start()
        self._threads.append(th)
        if self.straggler_factor:
            th = threading.Thread(target=self._straggler_loop, daemon=True,
                                  name="pool-straggler")
            th.start()
            self._threads.append(th)
        return self

    def stop(self):
        self._stop.set()
        # SIGTERM is the stop protocol: an idle worker exits inside its
        # handler (its blocked recv would just resume otherwise), a busy
        # one finishes its task first.  There are no stop envelopes --
        # on a queue every host's workers share they could land anywhere.
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        self.queues.wake_all()
        with self._lock:
            self._straggler_cond.notify_all()
        for p in self._procs:
            p.join(timeout=2)
            if p.is_alive():
                p.kill()
        for th in self._threads:
            th.join(timeout=2)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- supervisor (control plane only) --------------------------------------

    def _monitor_loop(self):
        control = self._control_channel()
        while not self._stop.is_set():
            try:
                envs = control.get_batch(self.intake_batch,
                                         cancel=self._stop)
            except (ConnectionError, OSError):
                return                      # broker died: fabric is gone
            if envs:
                # control events are cheap to lose on a crash (the parent
                # dies with its whole bookkeeping): ack up front so a slow
                # scan can never let the lease lapse into redelivery
                control.ack()
            with self._lock:
                for env in envs:
                    kind, tid, identity, topic, value = pickle.loads(env.data)
                    if kind == "started":
                        # the event carries everything a backup decision
                        # needs: start time and the worker's lease id
                        # (which addresses the envelope bytes the broker
                        # still holds).  A backup execution registers
                        # with backup_sent=True so it can never cascade
                        # a backup-of-a-backup.
                        t_start, lease, is_backup = value
                        self._inflight[tid] = {
                            "topic": topic, "started": t_start,
                            "worker": identity, "lease": lease,
                            "backup_sent": is_backup}
                        self.task_history.setdefault(tid, []).append(identity)
                    elif kind == "retry":
                        info = self._inflight.get(tid)
                        if info is not None:
                            info["started"] = None  # queued again, not running
                            info["lease"] = None    # worker acked: lease gone
                    elif kind == "done":
                        self._inflight.pop(tid, None)
                        if value is not None:
                            hist = self._runtimes.setdefault(topic, [])
                            hist.append(value)
                            del hist[:-50]
                if envs:
                    self._straggler_cond.notify_all()

    def _straggler_loop(self):
        while True:
            fire = []
            with self._lock:
                if self._stop.is_set():
                    return
                tnow = now()
                next_deadline = None
                for tid, info in self._inflight.items():
                    if (info["started"] is None or info["backup_sent"]
                            or info["lease"] is None):
                        continue
                    hist = self._runtimes.get(info["topic"], [])
                    if len(hist) < self.straggler_min_history:
                        continue
                    med = sorted(hist)[len(hist) // 2]
                    deadline = info["started"] + self.straggler_factor * med
                    if deadline <= tnow:
                        info["backup_sent"] = True
                        fire.append((tid, dict(info)))
                    elif next_deadline is None or deadline < next_deadline:
                        next_deadline = deadline
                if not fire:
                    if next_deadline is None:
                        self._straggler_cond.wait()
                    else:
                        # recompute now(): tnow predates the O(inflight)
                        # scan above, and waiting next_deadline - tnow
                        # would overshoot a deadline earned during it
                        self._straggler_cond.wait(max(next_deadline - now(),
                                                      0.0))
                    continue
            for tid, info in fire:
                # the supervisor holds no envelope bytes: the broker's
                # lease ledger does.  Ask it to clone the leased original
                # back onto the queue with placement exclusions merged
                # into the clone's meta (``Channel.backup``); the
                # original lease is untouched -- the slow worker may
                # still win, and the claim arbitrates.
                # Topology-aware placement: exclude the *whole origin
                # host* when a peer pools this topic (a whole host can be
                # the straggler -- paper's Theta runs); otherwise exclude
                # just the original worker and let a sibling process take
                # it.  The started events only ever come from this host's
                # own workers, so the origin host is always self.host.
                eligible = (self.backup_hosts.get(info["topic"], [])
                            if isinstance(self.backup_hosts, dict)
                            else self.backup_hosts)
                peers = [h for h in eligible if h != self.host]
                meta_update = {"exclude_worker": info["worker"]}
                if peers:
                    meta_update["exclude_host"] = self.host
                    target = peers[self._backup_rr % len(peers)]
                    self._backup_rr += 1
                else:
                    target = self.host
                try:
                    ok = self._request_channel(info["topic"]).backup(
                        info["lease"], tid, meta_update)
                except (ConnectionError, OSError, RuntimeError):
                    continue                # broker gone / torn down
                if ok:
                    # the recorded target is the intended landing (with
                    # exclude_host any non-origin host may take it; with
                    # two hosts -- the common case -- it is exact)
                    self.backup_targets[tid] = target

    # -- worker side ----------------------------------------------------------

    def _start_heartbeat(self, requests, on_cancelled=None):
        """Worker-side lease keepalive: one daemon thread per worker
        process renews the request-queue lease under execution at half
        the lease timeout, so tasks that legitimately outlive it are
        never redelivered while their worker is demonstrably alive.  The
        main loop publishes the lease id under ``hb_cond``; clearing it
        (task finished) or replacing it (next task) retires the old
        renewal.  A SIGKILL stops the heartbeat with the process --
        expiry-based redelivery is untouched for real deaths.

        The same cadence doubles as the preemption escalation probe:
        each beat asks the broker whether the running task id has been
        cancelled, and ``on_cancelled`` fires when it has.  A task that
        never calls ``report_intermediate`` (so the cooperative fused
        probe never runs) is still preempted within ~lease_timeout/2."""
        hb_cond = threading.Condition()
        current = [None]                    # (lease_id, task_id) or None
        interval = max(self.queues.transport.lease_timeout / 2.0, 0.05)

        def loop():
            while True:
                with hb_cond:
                    while current[0] is None:
                        hb_cond.wait()
                    lid, tid = current[0]
                    hb_cond.wait(interval)
                    still_running = (current[0] is not None
                                     and current[0][0] == lid)
                if still_running:
                    try:
                        # probe before renew: a cancelled task's lease was
                        # already revoked broker-side, so renewing it would
                        # be a wasted round-trip on a dead lease
                        if (on_cancelled is not None and tid is not None
                                and requests.is_cancelled(tid)):
                            on_cancelled(tid)
                            continue
                        # renew from this thread's own connection: leases
                        # are addressed (topic, kind, id), not per-socket.
                        # False = too late (already expired): the claim on
                        # the result put arbitrates, same as a straggler
                        requests.renew(lid)
                    except (ConnectionError, OSError, RuntimeError):
                        pass                # broker gone: worker exits soon

        threading.Thread(target=loop, daemon=True,
                         name="pool-heartbeat").start()

        def set_current(lid, tid=None):
            with hb_cond:
                current[0] = None if lid is None else (lid, tid)
                hb_cond.notify()

        return set_current

    def _worker_flush_and_exit(self):
        # cumulative metrics: the final snapshot supersedes the throttled
        # mid-run ones, so short-lived workers don't under-report
        obs.flush_metrics(force=True)
        vs = self.queues.value_server
        if vs is not None and hasattr(vs, "flush_replication"):
            # drain queued replica fan-outs (async release/put copies)
            # before dying: an op stranded in the background queue would
            # leave a replica holding a copy its primary already deleted
            try:
                vs.flush_replication(timeout=5.0)
            except Exception:               # noqa: BLE001
                pass
        os._exit(0)

    def _worker_main(self, topic: str, rank: int):
        identity = f"{self.host}/{topic}/w{rank}/pid{os.getpid()}"
        requests = self._request_channel(topic)
        control = self._control_channel()
        queues = self.queues
        # fabric-timeline identity (+ clock calibration against the
        # connected broker when tracing is on -- telemetry, never fatal)
        ref, offset = "", None
        if obs.enabled():
            try:
                offset = obs.calibrate(queues.transport.clock_sync)
                ref = obs.addr_str(queues.transport.address)
            except (ConnectionError, OSError, RuntimeError, KeyError,
                    TypeError, ValueError, AttributeError):
                offset = None
        obs.configure(role="worker", host=self.host, ref=ref, offset=offset)
        t_spawn = now()
        busy_total = 0.0
        cache: dict = {}
        stopping = [False]
        busy = [False]
        # preemption cells shared between the main thread (executes the
        # task), the heartbeat thread (probes the broker) and the SIGTERM
        # handler (runs on the main thread): one-cell lists, GIL-atomic
        current_tid = [None]                # task id under execution
        cancel_tid = [None]                 # heartbeat saw this id cancelled
        in_user_fn = [False]                # main thread is inside spec.fn
        cancel_pending = [False]            # deliver at next safe point

        def on_term(signum, frame):
            if cancel_tid[0] is not None and cancel_tid[0] == current_tid[0]:
                # preemption escalation: our own heartbeat signalled us
                # because the broker cancelled the running task.  Raise
                # ONLY while the main thread is inside the user function;
                # interrupting transport code would corrupt a frame
                # mid-send, so elsewhere we set the cooperative flag and
                # let report_intermediate (or the post-execute check)
                # convert it.
                if in_user_fn[0]:
                    raise streaming.TaskCancelled(current_tid[0])
                cancel_pending[0] = True
                return
            stopping[0] = True
            if not busy[0]:
                # idle: the main loop is parked in a blocking recv that
                # would simply *resume* when this handler returns (PEP
                # 475), so the exit must happen here.  No socket I/O from
                # the handler (the parked get owns this thread's
                # connection); an unflushed piggybacked ack just lets a
                # lease expire into a redelivery the claim dedups.
                self._worker_flush_and_exit()

        def on_cancelled(tid):
            # heartbeat thread -> main thread: signal handlers run on the
            # main thread, so a self-SIGTERM is a safe cross-thread
            # interrupt that lands exactly where on_term can judge it
            cancel_tid[0] = tid
            os.kill(os.getpid(), signal.SIGTERM)

        signal.signal(signal.SIGTERM, on_term)
        set_hb = self._start_heartbeat(requests, on_cancelled)
        while True:
            envs = requests.get_batch(1)
            if stopping[0]:
                requests.ack(flush=True)
                self._worker_flush_and_exit()
            if not envs:
                continue
            env = envs[0]
            meta = env.meta
            bounces = meta.get("bounces", 0)
            if ((meta.get("exclude_worker") == identity
                 or meta.get("exclude_host") == self.host)
                    and bounces < _MAX_BOUNCES):
                # backup placement: this envelope must run elsewhere (the
                # excluded worker is by definition still busy with the
                # original).  Bounce the bytes verbatim -- no unpickle --
                # and back off a little so an eligible worker wins the
                # next dequeue race.
                busy[0] = True
                meta = dict(meta)
                meta["bounces"] = bounces + 1
                requests.put(Envelope(env.t_put, env.data, meta))
                requests.ack()              # handed off: the re-put owns it
                busy[0] = False
                if stopping[0]:
                    requests.ack(flush=True)
                    self._worker_flush_and_exit()
                time.sleep(0.002 * (bounces + 1))
                continue
            busy[0] = True
            task = queues._decode_task(env)
            current_tid[0] = task.task_id
            control.put(Envelope(now(), pickle.dumps(
                ("started", task.task_id, identity, task.topic,
                 (now(), requests.held_lease(), meta.get("backup", False)))),
                {}))
            # heartbeat (and cancel probe) across the execution
            set_hb(requests.held_lease(), task.task_id)
            t_task = now()
            cancelled = False
            try:
                self._execute(task, identity, requests, control, cache,
                              in_user_fn, cancel_pending)
            except streaming.TaskCancelled:
                cancelled = True
            finally:
                set_hb(None)
                current_tid[0] = None
                cancel_tid[0] = None
                cancel_pending[0] = False
                in_user_fn[0] = False
                busy_total += now() - t_task
                obs.gauge("worker_busy_frac").set(
                    busy_total / max(now() - t_spawn, 1e-9))
                obs.flush_metrics()
            if cancelled:
                # preempted: the broker's cancel already claimed the id
                # and revoked this lease, so there is nothing to ack --
                # and we must NOT ack: were the interruption ever wrong
                # (stale probe), the unacked lease expires and the task
                # redelivers, preserving at-least-once.  Detach so the
                # channel forgets the dead lease instead of piggybacking
                # a bogus ack on the next frame.
                requests.detach_lease()
                control.put(Envelope(now(), pickle.dumps(
                    ("done", task.task_id, identity, task.topic, None)),
                    {}))
                busy[0] = False
                if stopping[0]:
                    requests.ack(flush=True)
                    self._worker_flush_and_exit()
                continue
            # the task reached a terminal handoff (result published, retry
            # requeued, or duplicate swallowed by the claim): release the
            # request-queue lease.  The ack piggybacks on the next frame
            # this worker sends; dying before it reaches the broker only
            # causes a redelivery whose completion the claim dedups.  Until
            # here the lease stays held, so a SIGKILL mid-execution expires
            # it and the broker redelivers the task to another worker.
            requests.ack()
            busy[0] = False
            if stopping[0]:
                requests.ack(flush=True)
                self._worker_flush_and_exit()

    def _execute(self, task: msg.Task, identity: str, requests, control,
                 cache: dict, in_user_fn: list, cancel_pending: list):
        queues = self.queues
        spec = self._methods[task.method]
        # sampling decision made at send_task rides the envelope meta;
        # _decode_task surfaced it (and the redelivery attempt number)
        # as dynamic attributes
        traced = bool(getattr(task, "trace", False))
        attempt = int(getattr(task, "attempt", 0) or 0)
        runtime = None
        try:
            args = resolve_tree(task.args, queues.value_server, cache,
                                async_start=True)
            kwargs = resolve_tree(task.kwargs, queues.value_server, cache,
                                  async_start=True)
            args = resolve_tree(args, queues.value_server, cache)
            kwargs = resolve_tree(kwargs, queues.value_server, cache)
            if traced:
                # written through to disk BEFORE execute: a SIGKILLed
                # attempt is evidenced by this instant with no closing
                # span, and the redelivered attempt starts its own
                # sub-trace at the next attempt number
                obs.instant(task.task_id, "task_started", attempt=attempt,
                            worker=identity)
            # streaming context: report_intermediate publishes on the
            # topic's stream lane; cancel_pending is the cell the SIGTERM
            # handler flips when the exception could not be raised in
            # place.  in_user_fn brackets spec.fn *strictly*: the handler
            # may only raise while the main thread is inside the user
            # frame (anywhere else could be mid-send on the socket).
            ctx = streaming.TaskContext(
                task.task_id, task.topic,
                stream=queues.stream_channel(task.topic),
                traced=traced, worker=identity,
                cancel_pending=cancel_pending)
            streaming.set_context(ctx)
            t0 = now()
            try:
                in_user_fn[0] = True
                value = spec.fn(*args, **kwargs)
            finally:
                in_user_fn[0] = False
                streaming.clear_context()
            ctx.check_cancelled()       # pending cancel -> unwind, no result
            runtime = now() - t0
            task.timer.record("execute", runtime)
            if traced:
                obs.span(task.task_id, "execute", t0, t0 + runtime,
                         attempt=attempt, worker=identity)
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=True, value=value, args=task.args,
                kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=identity)
        except streaming.TaskCancelled:
            # preemption is not a failure: never the retry path (that
            # would resubmit work the Thinker explicitly culled).  The
            # caller detaches the revoked lease and moves on.
            raise
        except Exception as e:                         # noqa: BLE001
            task.timer.record("execute", 0.0)
            if task.retries < spec.max_retries:
                task.retries += 1
                obs.counter("task_retries").inc()
                data = msg.serialize(task)
                retry_meta = {"input_size": task.input_size,
                              "task_id": task.task_id}
                if traced:
                    # the retry is a fresh attempt: keep it sampled and
                    # bump the attempt number its sub-trace carries
                    retry_meta["trace"] = 1
                    retry_meta["redelivered"] = attempt + 1
                requests.put(Envelope(now(), data, retry_meta))
                # tell the supervisor the attempt ended: clearing
                # 'started' stops the straggler monitor from firing a
                # backup for a task that is queued for retry, not
                # running anywhere
                control.put(Envelope(now(), pickle.dumps(
                    ("retry", task.task_id, identity, task.topic, None)),
                    {}))
                return
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=False, error=f"{e!r}\n{traceback.format_exc()}",
                args=task.args, kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=identity)

        # cross-process first-completion-wins, fused with the publish: the
        # broker claims the id and enqueues the result in one atomic op.
        # Always on (not just under straggler_factor): a lease-expiry
        # redelivery racing a slow-but-alive original is the same race as
        # a straggler backup and needs the same arbitration.
        result.attempt = attempt            # send_result tags its spans
        won = queues.send_result(result, claim_id=task.task_id)
        if won:
            obs.counter("tasks_completed").inc()
            queues.release_task_inputs(task)
        control.put(Envelope(now(), pickle.dumps(
            ("done", task.task_id, identity, task.topic, runtime)), {}))
