"""ProcessPoolTaskServer: registered methods execute in worker OS processes.

The thread-pool ``TaskServer`` gives concurrency; this one gives the
paper's topology -- N *processes* per topic (Parsl workers), true
parallelism for CPU-bound simulation tasks, and per-worker **identity**
(``host/topic/wR/pidP``) so placement decisions are possible.  It requires
the ``proc`` queue backend: the parent (dispatcher) and the workers only
ever meet through the broker.

Dispatch path (envelope bytes are *relayed*, never re-pickled)::

    Thinker --put--> topic requests --intake (parent)--> pool:<topic>
            <--put-- topic results  <------------------- worker executes

The parent's intake thread records each in-flight envelope (keyed by the
``task_id`` riding the envelope meta -- no unpickle on the hot path) and
forwards the bytes verbatim to the pool's dispatch channel, which workers
drain with blocking batched gets.  Workers report ``started`` / ``done``
events on a control channel, giving the parent the per-task worker
identity and runtime history.

Straggler mitigation with *placement*: when a task exceeds
``straggler_factor`` x the topic's trailing-median runtime, the parent
re-dispatches a backup with ``exclude_worker`` set to the identity that
started the original -- a worker that sees its own identity excluded
bounces the task back (the original is, by definition, still busy, so an
idle *different* worker picks it up).  First completion wins: workers
arbitrate via the broker's atomic ``claim`` op, so exactly one result per
task id reaches the Thinker even though the racers live in different
processes.

Topology awareness: every pool carries a **host identity** (``host=``;
defaults to the real hostname) that prefixes each worker identity and
scopes the pool's dispatch/control channels (``pool@<host>:<topic>``),
so in a multi-host federation worker <-> dispatch traffic stays on the
worker's local broker.  ``backup_hosts`` names peer hosts running pools
for the same topics: the straggler monitor then places backups on a
*different host* than the original (round-robin over the peers) --
surviving a whole-host slowdown, not just a slow process -- and falls
back to the same-host exclude/bounce dance only when no peer exists.

Long tasks and leases: each worker runs a heartbeat thread that renews
the dispatch-channel lease at half its timeout while a task executes,
so work that legitimately outlives ``lease_timeout`` keeps its lease
instead of triggering a wasteful redelivery that the claim then has to
dedup.  A SIGKILLed worker stops heartbeating, its lease expires, and
the task redelivers -- exactly as before.

Fault tolerance mirrors the thread server -- per-task retry with capped
attempts, errors captured into the Result, one-shot Value-Server inputs
released by the winning worker only -- and adds **exactly-once dispatch**
on top of the transport's leases: a worker holds its dispatch-channel
lease for the task's whole execution and only acks after the result is
published, so a worker SIGKILLed mid-task (or a response frame lost with
its connection) leaves an unacked lease that expires and redelivers the
task to a *different* worker.  Completions arbitrate via the claim fused
into the result ``put``, so a redelivery racing a slow-but-alive
original -- like a straggler backup racing its original -- yields exactly
one result per task id.

Workers are **forked** (not spawned): registered methods may be closures
or lambdas, which only fork can inherit.  CPython >= 3.12 warns about
forking a multi-threaded process; the children here never touch the
parent's thread state -- they immediately enter the dispatch loop and
only run stdlib/pickle/numpy plus the registered method -- and every
socket client reconnects per-pid, so the warning is benign for this
usage.  Fork workers *before* starting Thinker agent threads (the
``with pool:`` idiom does this naturally).
"""
from __future__ import annotations

import os
import pickle
import socket as socketlib
import threading
import time
import traceback
from typing import Callable, Dict, Optional

from repro.core import message as msg
from repro.core.queues import ColmenaQueues
from repro.core.task_server import MethodSpec
from repro.core.transport import Envelope
from repro.core.transport.base import BoundedDict
from repro.core.value_server import ValueServer, resolve_tree
from repro.utils.timing import now

_MAX_BOUNCES = 16       # prefer progress over placement after this many

POOL_PREFIX = "pool@"


def dispatch_topic(host: str, topic: str) -> str:
    """The per-host pool dispatch channel for ``topic``.  In a
    federation the ``pool@<host>:`` prefix homes the channel at that
    host's broker (``cluster.spec.resolve_home``), keeping worker <->
    dispatch traffic on-host; cross-host straggler backups target a
    *peer* host's channel by the same naming."""
    return f"{POOL_PREFIX}{host}:{topic}"


def control_topic(host: str) -> str:
    """Per-host pool control channel: each parent monitors only its own
    workers' events (a shared control topic across hosts would race on
    leases and split events randomly between monitors)."""
    return f"{POOL_PREFIX}{host}:__control__"


def host_of(identity: str) -> str:
    """The host component of a worker identity (``host/topic/wR/pidP``)."""
    return identity.split("/", 1)[0]


class ProcessPoolTaskServer:
    def __init__(self, queues: ColmenaQueues, *, workers_per_topic=2,
                 straggler_factor: Optional[float] = None,
                 straggler_min_history: int = 5, intake_batch: int = 32,
                 history_window: int = 4096,
                 host: Optional[str] = None,
                 backup_hosts: Optional[list] = None):
        """workers_per_topic: an int (uniform) or a {topic: n} dict (a
        cluster host runs only the pools its HostSpec lists, with
        per-topic sizes).  host: this pool's host identity; None uses
        the real hostname.  Simulated hosts sharing one machine pass
        distinct names so placement decisions stay meaningful.
        backup_hosts: peer hosts running pools for the same topics --
        straggler backups prefer one of them over the original's host.
        Either a flat list (every topic) or a {topic: [hosts]} dict (a
        backup must only target a host that actually pools its topic,
        or the backup envelope would sit in an undrained channel)."""
        if queues.backend != "proc":
            raise ValueError(
                "ProcessPoolTaskServer requires ColmenaQueues(backend='proc')"
                " -- worker processes can only reach a socket-backed fabric")
        if isinstance(queues.value_server, ValueServer):
            raise ValueError(
                "an in-process ValueServer is invisible to worker processes;"
                " use transport.shards.ShardedValueServer (or None)")
        self.queues = queues
        self.straggler_factor = straggler_factor
        self.straggler_min_history = straggler_min_history
        self.intake_batch = intake_batch
        self._workers_per_topic = workers_per_topic
        self.host = host or socketlib.gethostname()
        self.backup_hosts = backup_hosts or []
        self._backup_rr = 0                    # round-robin over peers
        self.backup_targets: Dict[str, str] = {}  # task_id -> backup host
        self._methods: Dict[str, MethodSpec] = {}
        self._procs: list = []
        self._threads: list = []
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._straggler_cond = threading.Condition(self._lock)
        self._inflight: Dict[str, dict] = {}   # task_id -> info
        self._runtimes: Dict[str, list] = {}   # topic -> recent runtimes
        # task_id -> [identities that *started* it], for tests/diagnostics;
        # sliding-window bounded (BoundedIdSet's eviction pattern) so the
        # map cannot grow without limit over a long campaign
        self.task_history = BoundedDict(history_window)

    # -- registration ---------------------------------------------------------

    def register(self, fn: Callable, *, topic: Optional[str] = None,
                 name: Optional[str] = None, max_retries: int = 1):
        name = name or fn.__name__
        topic = topic or name
        self._methods[name] = MethodSpec(fn, topic=topic,
                                         max_retries=max_retries)
        return name

    # -- channels -------------------------------------------------------------

    def _dispatch_channel(self, topic: str, host: Optional[str] = None):
        return self.queues.transport.channel(
            dispatch_topic(host or self.host, topic), "tasks")

    def _control_channel(self):
        return self.queues.transport.channel(control_topic(self.host),
                                             "events")

    def _n_workers(self, topic: str) -> int:
        if isinstance(self._workers_per_topic, dict):
            return self._workers_per_topic.get(topic, 0)
        return self._workers_per_topic

    # -- lifecycle ------------------------------------------------------------

    def start(self):
        import multiprocessing
        ctx = multiprocessing.get_context("fork")
        topics = self.queues.topics()
        for topic in topics:
            if self._n_workers(topic) == 0:
                continue                    # this host does not pool it
            for rank in range(self._n_workers(topic)):
                p = ctx.Process(target=self._worker_main, args=(topic, rank),
                                daemon=True, name=f"pool-{topic}-w{rank}")
                p.start()
                self._procs.append(p)
            th = threading.Thread(target=self._intake_loop, args=(topic,),
                                  daemon=True, name=f"pool-intake-{topic}")
            th.start()
            self._threads.append(th)
        th = threading.Thread(target=self._monitor_loop, daemon=True,
                              name="pool-monitor")
        th.start()
        self._threads.append(th)
        if self.straggler_factor:
            th = threading.Thread(target=self._straggler_loop, daemon=True,
                                  name="pool-straggler")
            th.start()
            self._threads.append(th)
        return self

    def stop(self):
        self._stop.set()
        try:
            for topic in self.queues.topics():
                ch = self._dispatch_channel(topic)
                for _ in range(self._n_workers(topic)):
                    ch.put(Envelope(now(), b"", {"stop": True}))
        except (ConnectionError, OSError):
            pass    # broker already dead: workers die with their sockets
        self.queues.wake_all()
        with self._lock:
            self._straggler_cond.notify_all()
        for p in self._procs:
            p.join(timeout=2)
            if p.is_alive():
                p.terminate()
        for th in self._threads:
            th.join(timeout=2)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- parent side ----------------------------------------------------------

    def _intake_loop(self, topic: str):
        requests = self.queues._topics[topic].requests
        dispatch = self._dispatch_channel(topic)
        while not self._stop.is_set():
            try:
                envs = requests.get_batch(self.intake_batch,
                                          cancel=self._stop)
            except (ConnectionError, OSError):
                return                      # broker died: fabric is gone
            if not envs:
                continue                    # woken for shutdown; loop checks
            with self._lock:
                for env in envs:
                    tid = env.meta.get("task_id")
                    if tid is not None:
                        self._inflight[tid] = {
                            "env": env, "topic": topic, "started": None,
                            "worker": None, "backup_sent": False}
                self._straggler_cond.notify_all()
            for env in envs:
                dispatch.put(env)           # bytes relayed verbatim
            # every envelope is now on the pool dispatch queue (itself
            # leased until a worker completes it): commit the intake lease
            requests.ack()

    def _monitor_loop(self):
        control = self._control_channel()
        while not self._stop.is_set():
            try:
                envs = control.get_batch(self.intake_batch,
                                         cancel=self._stop)
            except (ConnectionError, OSError):
                return                      # broker died: fabric is gone
            if envs:
                # control events are cheap to lose on a crash (the parent
                # dies with its whole bookkeeping): ack up front so a slow
                # scan can never let the lease lapse into redelivery
                control.ack()
            with self._lock:
                for env in envs:
                    kind, tid, identity, topic, value = pickle.loads(env.data)
                    if kind == "started":
                        info = self._inflight.get(tid)
                        if info is not None:
                            info["started"] = value
                            info["worker"] = identity
                        self.task_history.setdefault(tid, []).append(identity)
                    elif kind == "retry":
                        info = self._inflight.get(tid)
                        if info is not None:
                            info["started"] = None  # queued again, not running
                    elif kind == "done":
                        self._inflight.pop(tid, None)
                        if value is not None:
                            hist = self._runtimes.setdefault(topic, [])
                            hist.append(value)
                            del hist[:-50]
                if envs:
                    self._straggler_cond.notify_all()

    def _straggler_loop(self):
        while True:
            fire = []
            with self._lock:
                if self._stop.is_set():
                    return
                tnow = now()
                next_deadline = None
                for tid, info in self._inflight.items():
                    if info["started"] is None or info["backup_sent"]:
                        continue
                    hist = self._runtimes.get(info["topic"], [])
                    if len(hist) < self.straggler_min_history:
                        continue
                    med = sorted(hist)[len(hist) // 2]
                    deadline = info["started"] + self.straggler_factor * med
                    if deadline <= tnow:
                        info["backup_sent"] = True
                        fire.append((tid, info))
                    elif next_deadline is None or deadline < next_deadline:
                        next_deadline = deadline
                if not fire:
                    if next_deadline is None:
                        self._straggler_cond.wait()
                    else:
                        # recompute now(): tnow predates the O(inflight)
                        # scan above, and waiting next_deadline - tnow
                        # would overshoot a deadline earned during it
                        self._straggler_cond.wait(max(next_deadline - now(),
                                                      0.0))
                    continue
            for tid, info in fire:
                # decode only here (backups are rare): rebuild the task with
                # backup placement metadata and re-dispatch
                task: msg.Task = msg.deserialize(info["env"].data)
                task.is_backup = True
                task.exclude_worker = info["worker"]
                # topology-aware placement: prefer a *different host* than
                # the original's (a whole host can be the straggler --
                # paper's Theta runs); round-robin over eligible peers.
                # Fall back to this host's own channel, where the exclude
                # bounce finds a different worker process.
                origin = (host_of(info["worker"]) if info["worker"]
                          else self.host)
                eligible = (self.backup_hosts.get(info["topic"], [])
                            if isinstance(self.backup_hosts, dict)
                            else self.backup_hosts)
                peers = [h for h in eligible
                         if h != origin and h != self.host]
                if peers:
                    target = peers[self._backup_rr % len(peers)]
                    self._backup_rr += 1
                else:
                    target = self.host
                self.backup_targets[tid] = target
                data = msg.serialize(task)
                self._dispatch_channel(info["topic"], host=target).put(
                    Envelope(now(), data,
                             {"input_size": len(data),
                              "task_id": task.task_id}))

    # -- worker side ----------------------------------------------------------

    def _start_heartbeat(self, dispatch):
        """Worker-side lease keepalive: one daemon thread per worker
        process renews the dispatch lease under execution at half the
        lease timeout, so tasks that legitimately outlive it are never
        redelivered while their worker is demonstrably alive.  The main
        loop publishes the lease id under ``hb_cond``; clearing it (task
        finished) or replacing it (next task) retires the old renewal.
        A SIGKILL stops the heartbeat with the process -- expiry-based
        redelivery is untouched for real deaths."""
        hb_cond = threading.Condition()
        current = [None]
        interval = max(self.queues.transport.lease_timeout / 2.0, 0.05)

        def loop():
            while True:
                with hb_cond:
                    while current[0] is None:
                        hb_cond.wait()
                    lid = current[0]
                    hb_cond.wait(interval)
                    still_running = current[0] == lid
                if still_running:
                    try:
                        # renew from this thread's own connection: leases
                        # are addressed (topic, kind, id), not per-socket.
                        # False = too late (already expired): the claim on
                        # the result put arbitrates, same as a straggler
                        dispatch.renew(lid)
                    except (ConnectionError, OSError, RuntimeError):
                        pass                # broker gone: worker exits soon

        threading.Thread(target=loop, daemon=True,
                         name="pool-heartbeat").start()

        def set_current(lid):
            with hb_cond:
                current[0] = lid
                hb_cond.notify()

        return set_current

    def _worker_main(self, topic: str, rank: int):
        identity = f"{self.host}/{topic}/w{rank}/pid{os.getpid()}"
        dispatch = self._dispatch_channel(topic)
        control = self._control_channel()
        queues = self.queues
        cache: dict = {}
        set_hb = self._start_heartbeat(dispatch)
        while True:
            envs = dispatch.get_batch(1)
            if not envs:
                continue
            env = envs[0]
            if env.meta.get("stop"):
                dispatch.ack(flush=True)    # don't strand the stop envelope
                vs = queues.value_server
                if vs is not None and hasattr(vs, "flush_replication"):
                    # drain queued replica fan-outs (async release/put
                    # copies) before dying: an op stranded in the
                    # background queue would leave a replica holding a
                    # copy its primary already deleted
                    vs.flush_replication(timeout=5.0)
                os._exit(0)
            task = queues._decode_task(env)
            if (task.exclude_worker == identity
                    and task.bounces < _MAX_BOUNCES):
                # backup placement: this is the worker running the original
                task.bounces += 1
                data = msg.serialize(task)
                dispatch.put(Envelope(now(), data,
                                      {"input_size": task.input_size,
                                       "task_id": task.task_id}))
                dispatch.ack()              # handed off: the re-put owns it
                time.sleep(0.002 * task.bounces)
                continue
            control.put(Envelope(now(), pickle.dumps(
                ("started", task.task_id, identity, task.topic, now())),
                {}))
            set_hb(dispatch.held_lease())   # heartbeat across the execution
            try:
                self._execute(task, identity, dispatch, control, cache)
            finally:
                set_hb(None)
            # the task reached a terminal handoff (result published, retry
            # requeued, or duplicate swallowed by the claim): release the
            # dispatch lease.  The ack piggybacks on the next frame this
            # worker sends; dying before it reaches the broker only causes
            # a redelivery whose completion the claim dedups.  Until here
            # the lease stays held, so a SIGKILL mid-execution expires it
            # and the broker redelivers the task to another worker.
            dispatch.ack()

    def _execute(self, task: msg.Task, identity: str, dispatch, control,
                 cache: dict):
        queues = self.queues
        spec = self._methods[task.method]
        runtime = None
        try:
            args = resolve_tree(task.args, queues.value_server, cache,
                                async_start=True)
            kwargs = resolve_tree(task.kwargs, queues.value_server, cache,
                                  async_start=True)
            args = resolve_tree(args, queues.value_server, cache)
            kwargs = resolve_tree(kwargs, queues.value_server, cache)
            t0 = now()
            value = spec.fn(*args, **kwargs)
            runtime = now() - t0
            task.timer.record("execute", runtime)
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=True, value=value, args=task.args,
                kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=identity)
        except Exception as e:                         # noqa: BLE001
            task.timer.record("execute", 0.0)
            if task.retries < spec.max_retries:
                task.retries += 1
                data = msg.serialize(task)
                dispatch.put(Envelope(now(), data,
                                      {"input_size": task.input_size,
                                       "task_id": task.task_id}))
                # tell the parent the attempt ended: clearing 'started'
                # stops the straggler monitor from firing a backup for a
                # task that is queued for retry, not running anywhere
                control.put(Envelope(now(), pickle.dumps(
                    ("retry", task.task_id, identity, task.topic, None)),
                    {}))
                return
            result = msg.Result(
                task_id=task.task_id, topic=task.topic, method=task.method,
                success=False, error=f"{e!r}\n{traceback.format_exc()}",
                args=task.args, kwargs=task.kwargs, timer=task.timer,
                input_size=task.input_size, worker=identity)

        # cross-process first-completion-wins, fused with the publish: the
        # broker claims the id and enqueues the result in one atomic op.
        # Always on (not just under straggler_factor): a lease-expiry
        # redelivery racing a slow-but-alive original is the same race as
        # a straggler backup and needs the same arbitration.
        won = queues.send_result(result, claim_id=task.task_id)
        if won:
            queues.release_task_inputs(task)
        control.put(Envelope(now(), pickle.dumps(
            ("done", task.task_id, identity, task.topic, runtime)), {}))
