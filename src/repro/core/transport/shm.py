"""Shared-memory payload lane: co-located frames skip the socket body.

Control and data take different paths.  A frame whose payload crosses
``SHM_THRESHOLD`` between processes on the *same machine* is split: the
header (tiny, pickled) still rides the socket, but the payload is
written once into a named shared-memory **segment** and the header
carries an out-of-band descriptor (``{"name", "size"}``) instead of the
bytes.  The receiver maps the segment and reads the payload in place --
the body never transits a socket buffer, is never copied into the
broker, and for a queued envelope is read exactly twice (producer write,
consumer read) instead of four socket copies.

Segments are plain files in the POSIX shared-memory namespace
(``/dev/shm`` tmpfs; ``shm_open`` semantics), accessed with ``mmap``.
``multiprocessing.shared_memory`` is deliberately NOT used: on this
interpreter (< 3.13, no ``track=False``) every *attaching* process
registers the segment with its resource tracker, which unlinks it when
that process exits -- a consumer reading a broker-owned segment would
destroy it for everyone else (bpo-39959).  Raw tmpfs files give the
exact create/unlink control the ownership protocol below needs, and a
sweep is just a directory listing.

Ownership protocol (tied to the lease/ack lifecycle):

1. The **producer** creates the segment and sends the descriptor.  Until
   the broker's response arrives the producer is the owner: a send error
   unlinks the segment (nothing references it).  On a *connection* error
   the broker may or may not have received the frame, so the producer
   must NOT unlink -- a leak swept at fabric teardown is recoverable, a
   destroyed segment under a delivered envelope is a lost task.
2. The **broker** owns the segment from frame receipt to envelope
   destruction: a rejected claim unlinks immediately; an acked lease
   unlinks; an *expired* lease redelivers the descriptor intact (the
   SIGKILLed consumer never owned the segment, so its death can neither
   leak it past the broker's registry nor double-free it).
3. **Consumers** only ever map and read.  They never unlink.
4. ``sweep_scope`` removes every segment of a fabric's scope token --
   run at transport teardown (after the broker is down) it reclaims the
   only reachable leaks: producer died pre-handoff, or the broker itself
   was SIGKILLed.  Scope tokens are per-fabric, so sweeping a dead
   fabric can never touch a live one's segments.

Descriptors are flat dicts of literal keys (``name``/``size``) so the
frame-header hygiene lint can check them like any other header field.
"""
from __future__ import annotations

import mmap
import os
import threading
import uuid
from typing import Optional

SHM_PREFIX = "colmena-seg-"
SHM_THRESHOLD = 256 * 1024          # bytes; >= this rides shared memory

_DIRS = ("/dev/shm", "/run/shm")


def shm_dir() -> Optional[str]:
    """The machine's POSIX shm mount (None disables the lane, e.g. on
    platforms without a tmpfs shm namespace)."""
    for d in _DIRS:
        if os.path.isdir(d) and os.access(d, os.W_OK):
            return d
    return None


def new_scope() -> str:
    """A fabric-unique scope token baked into every segment name, so
    teardown can sweep exactly one fabric's segments."""
    return uuid.uuid4().hex[:12]


_counter_lock = threading.Lock()
_counter = 0


def _next_name(scope: str) -> str:
    global _counter
    with _counter_lock:
        _counter += 1
        n = _counter
    return f"{SHM_PREFIX}{scope}-{os.getpid()}-{n}"


def create_segment(scope: str, payload) -> Optional[dict]:
    """Write ``payload`` into a fresh segment; returns its descriptor
    (flat, literal keys -- it travels in a frame header) or None when
    the machine has no shm namespace.  The caller owns the segment until
    it hands the descriptor off (see the module's ownership protocol);
    on any error during the write the segment is unlinked here -- the
    error path can never leak a half-written segment."""
    d = shm_dir()
    if d is None:
        return None
    name = _next_name(scope)
    path = os.path.join(d, name)
    fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o600)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
    except BaseException:
        try:
            os.unlink(path)
        except OSError:
            pass
        raise
    return {"name": name, "size": len(payload)}


def read_segment(desc: dict) -> bytes:
    """Map the segment and copy its payload out (one read; the socket
    path would have copied it at least twice more).  Consumers call this
    and nothing else -- never unlink."""
    d = shm_dir()
    if d is None:
        raise FileNotFoundError("no shm namespace on this machine")
    size = desc["size"]
    fd = os.open(os.path.join(d, desc["name"]), os.O_RDONLY)
    try:
        if size == 0:
            return b""
        with mmap.mmap(fd, size, prot=mmap.PROT_READ) as m:
            return bytes(m)
    finally:
        os.close(fd)


def unlink_segment(desc: dict) -> None:
    """Destroy a segment (owner only).  Idempotent: unlinking a name
    twice, or one already swept, is a no-op -- segment names are never
    reused, so a double unlink cannot hit an innocent bystander."""
    d = shm_dir()
    if d is None:
        return
    try:
        os.unlink(os.path.join(d, desc["name"]))
    except OSError:
        pass


def sweep_scope(scope: str) -> list:
    """Unlink every segment of ``scope``; returns the swept names.  Only
    safe once the scope's fabric is down (its broker no longer serves
    any descriptor) -- the launcher/transport teardown path, or a test
    asserting no leaks."""
    d = shm_dir()
    if d is None:
        return []
    prefix = f"{SHM_PREFIX}{scope}-"
    swept = []
    try:
        names = os.listdir(d)
    except OSError:
        return []
    for name in names:
        if name.startswith(prefix):
            try:
                os.unlink(os.path.join(d, name))
                swept.append(name)
            except OSError:
                pass
    return swept


def live_segments(scope: str) -> list:
    """Segment names currently present for ``scope`` (diagnostics and
    the leak assertions in the chaos tests)."""
    d = shm_dir()
    if d is None:
        return []
    prefix = f"{SHM_PREFIX}{scope}-"
    try:
        return sorted(n for n in os.listdir(d) if n.startswith(prefix))
    except OSError:
        return []
