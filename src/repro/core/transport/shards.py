"""Sharded Value Server over the socket fabric.

Each ``ValueServerShard`` is a process holding one ``ValueServer`` (with
its own ``capacity_bytes`` LRU bound and spill-to-disk tier) and serving
it over the frame protocol: values travel as the client's pickle bytes and
are stored *as bytes*, so a shard never re-pickles payloads and the spill
files round-trip byte-identically.

``ShardedValueServer`` is the client: it implements the exact in-process
``ValueServer`` API (put/get/add_ref/release/delete/size_of/prefetch/
stats) so ``ColmenaQueues`` proxies and worker caches are oblivious to the
deployment.  Keys are routed by **consistent hashing** (md5 ring with
virtual nodes): adding a shard moves only ~1/N of the key space, matching
how a multi-host deployment would rebalance.  The client is fork-safe
(``FrameClient`` reopens connections per pid), which is how pool workers
in other processes resolve the same proxies.
"""
from __future__ import annotations

import atexit
import bisect
import hashlib
import multiprocessing
import os
import pickle
import tempfile
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple
import uuid

from repro.core.transport import frames

_mp = multiprocessing.get_context("fork")


class HashRing:
    """Consistent-hash ring over shard indices (md5, virtual nodes)."""

    def __init__(self, n_nodes: int, vnodes: int = 64):
        points: List[Tuple[int, int]] = []
        for node in range(n_nodes):
            for v in range(vnodes):
                h = hashlib.md5(f"shard-{node}:{v}".encode()).digest()
                points.append((int.from_bytes(h[:8], "big"), node))
        points.sort()
        self._hashes = [p[0] for p in points]
        self._nodes = [p[1] for p in points]

    def node(self, key: str) -> int:
        h = int.from_bytes(
            hashlib.md5(key.encode()).digest()[:8], "big")
        i = bisect.bisect(self._hashes, h) % len(self._hashes)
        return self._nodes[i]


# ---------------------------------------------------------------------------
# Shard server process
# ---------------------------------------------------------------------------


def _shard_main(sock, capacity_bytes: Optional[int], spill_dir: Optional[str],
                fetch_bandwidth: Optional[float]) -> None:
    from repro.core.value_server import ValueServer
    vs = ValueServer(capacity_bytes=capacity_bytes, spill_dir=spill_dir,
                     fetch_bandwidth=fetch_bandwidth)

    def handle(header: dict, payload: bytes):
        op = header["op"]
        if op == "vs_put":
            # stored as the client's pickle bytes: never re-pickled here
            key = vs.put(payload, size=header["size"], refs=header["refs"],
                         key=header["key"])
            return {"key": key}, b""
        if op == "vs_get":
            try:
                return {"ok": True}, vs.get(header["key"])
            except KeyError:
                return {"ok": False}, b""
        if op == "vs_add_ref":
            vs.add_ref(header["key"])
            return {"ok": True}, b""
        if op == "vs_release":
            return {"deleted": vs.release(header["key"])}, b""
        if op == "vs_delete":
            vs.delete(header["key"])
            return {"ok": True}, b""
        if op == "vs_size_of":
            try:
                return {"size": vs.size_of(header["key"])}, b""
            except KeyError:
                return {"size": None}, b""
        if op == "vs_contains":
            return {"in": header["key"] in vs}, b""
        if op == "vs_stats":
            return {"stats": dict(vs.stats), "len": len(vs),
                    "bytes": vs.total_bytes,
                    "spilled_bytes": vs.spilled_bytes}, b""
        if op == "ping":
            return {"ok": True}, b""
        if op == "shutdown":
            return None
        return {"error": f"unknown op {op!r}"}, b""

    frames.serve_forever(sock, handle, threading.Event())


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------


class ShardedValueServer:
    """Drop-in ValueServer client routing keys to shard processes.

    ``capacity_bytes`` is **per shard**; with ``spill=True`` each shard
    gets its own spill directory under a shared temp root, so the
    aggregate working set is ``num_shards * capacity_bytes`` in memory
    plus unbounded disk."""

    def __init__(self, num_shards: int = 2, *,
                 capacity_bytes: Optional[int] = None,
                 spill: bool = False,
                 fetch_bandwidth: Optional[float] = None,
                 vnodes: int = 64):
        assert num_shards >= 1
        self.num_shards = num_shards
        self._dir = tempfile.mkdtemp(prefix="colmena-vs-")
        self._owner_pid = os.getpid()
        self._procs = []
        self._clients: List[frames.FrameClient] = []
        for i in range(num_shards):
            sock, address = frames.make_server_socket(
                os.path.join(self._dir, f"shard{i}.sock"))
            spill_dir = (os.path.join(self._dir, f"spill{i}")
                         if spill else None)
            p = _mp.Process(target=_shard_main,
                            args=(sock, capacity_bytes, spill_dir,
                                  fetch_bandwidth),
                            daemon=True, name=f"colmena-vs-shard{i}")
            p.start()
            sock.close()
            self._procs.append(p)
            self._clients.append(frames.FrameClient(address))
        self._ring = HashRing(num_shards, vnodes=vnodes)
        self._resolver: Optional[ThreadPoolExecutor] = None
        self._resolver_pid = None
        atexit.register(self.shutdown)

    @classmethod
    def connect(cls, addresses: List[tuple],
                vnodes: int = 64) -> "ShardedValueServer":
        """Attach to already-running shard processes (a cluster
        launcher's) instead of spawning them.  Every client must pass
        the same ordered address list: the consistent-hash ring is
        positional, so an agreed order is what makes two clients route
        a key to the same shard.  ``shutdown`` on a connected client is
        a no-op -- the launcher owns the shard processes."""
        assert addresses, "connect() needs at least one shard address"
        self = cls.__new__(cls)
        self.num_shards = len(addresses)
        self._dir = None
        self._owner_pid = None              # not ours to shut down
        self._procs = []
        self._clients = [frames.FrameClient(tuple(a)) for a in addresses]
        self._ring = HashRing(self.num_shards, vnodes=vnodes)
        self._resolver = None
        self._resolver_pid = None
        return self

    def shard_of(self, key: str) -> int:
        return self._ring.node(key)

    def _client(self, key: str) -> frames.FrameClient:
        return self._clients[self._ring.node(key)]

    # -- ValueServer API ------------------------------------------------------

    def put(self, value, *, size: Optional[int] = None, refs: int = 0) -> str:
        data = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        if size is None:
            size = len(data)
        key = uuid.uuid4().hex
        # key is minted client-side so routing needs no coordination; the
        # shard adopts it verbatim
        header, _ = self._client(key).request(
            {"op": "vs_put", "key": key, "size": size, "refs": refs}, data)
        return header["key"]

    def get(self, key: str):
        # retry=True is safe: vs_get is a read-only probe
        header, payload = self._client(key).request(
            {"op": "vs_get", "key": key}, retry=True)
        if not header["ok"]:
            raise KeyError(key)
        return pickle.loads(payload)

    def add_ref(self, key: str) -> None:
        self._client(key).request({"op": "vs_add_ref", "key": key})

    def release(self, key: str) -> bool:
        header, _ = self._client(key).request(
            {"op": "vs_release", "key": key})
        return header["deleted"]

    def delete(self, key: str) -> None:
        # retry=True is safe: deleting an already-deleted key is a no-op,
        # so a resend of an applied delete converges to the same state
        self._client(key).request({"op": "vs_delete", "key": key}, retry=True)

    def size_of(self, key: str) -> int:
        # retry=True is safe: vs_size_of is a read-only probe
        header, _ = self._client(key).request(
            {"op": "vs_size_of", "key": key}, retry=True)
        if header["size"] is None:
            raise KeyError(key)
        return header["size"]

    def __contains__(self, key: str) -> bool:
        # retry=True is safe: vs_contains is a read-only probe
        header, _ = self._client(key).request(
            {"op": "vs_contains", "key": key}, retry=True)
        return header["in"]

    def prefetch(self, key: str) -> Future:
        # the executor is per-process: a forked worker lazily builds its own
        if self._resolver is None or self._resolver_pid != os.getpid():
            self._resolver = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="vs-resolve")
            self._resolver_pid = os.getpid()
        return self._resolver.submit(self.get, key)

    # -- introspection --------------------------------------------------------

    def per_shard_stats(self) -> List[dict]:
        out = []
        for c in self._clients:
            # retry=True is safe: vs_stats is a read-only probe
            header, _ = c.request({"op": "vs_stats"}, retry=True)
            out.append({"len": header["len"], "bytes": header["bytes"],
                        "spilled_bytes": header["spilled_bytes"],
                        **header["stats"]})
        return out

    @property
    def stats(self) -> Dict[str, int]:
        # aggregate only the counters the in-process ValueServer.stats has
        # (len/bytes/spilled_bytes live on their own properties), keeping
        # the drop-in key set identical across deployments
        agg: Dict[str, int] = {}
        for c in self._clients:
            # retry=True is safe: vs_stats is a read-only probe
            header, _ = c.request({"op": "vs_stats"}, retry=True)
            for k, v in header["stats"].items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def __len__(self) -> int:
        return sum(s["len"] for s in self.per_shard_stats())

    @property
    def total_bytes(self) -> int:
        return sum(s["bytes"] for s in self.per_shard_stats())

    @property
    def spilled_bytes(self) -> int:
        return sum(s["spilled_bytes"] for s in self.per_shard_stats())

    def shutdown(self) -> None:
        if os.getpid() != self._owner_pid or not self._procs:
            return
        procs, self._procs = self._procs, []
        for c in self._clients:
            try:
                c.request({"op": "shutdown"})
            except (ConnectionError, OSError):
                pass
            c.close()
        for p in procs:
            p.join(timeout=2)
            if p.is_alive():
                p.terminate()
        import shutil
        shutil.rmtree(self._dir, ignore_errors=True)
