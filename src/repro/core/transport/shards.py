"""Sharded Value Server over the socket fabric — durable and elastic.

Each ``ValueServerShard`` is a process holding one ``ValueServer`` (with
its own ``capacity_bytes`` LRU bound and spill-to-disk tier) and serving
it over the frame protocol: values travel as the client's pickle bytes and
are stored *as bytes*, so a shard never re-pickles payloads and the spill
files round-trip byte-identically.

``ShardedValueServer`` is the client: it implements the exact in-process
``ValueServer`` API (put/get/add_ref/release/delete/size_of/prefetch/
stats) so ``ColmenaQueues`` proxies and worker caches are oblivious to the
deployment.  Keys are routed by **consistent hashing** (md5 ring with
virtual nodes over stable shard ids); the client is fork-safe
(``FrameClient`` reopens connections per pid), which is how pool workers
in other processes resolve the same proxies.

Durability (this module's three load-bearing guarantees):

- **Replication** (``replicas=R``): every key is written to the R distinct
  successor shards of its ring position.  The hot path is primary-ack --
  the first live successor acknowledges synchronously, the remaining
  copies fan out through a background replication thread (one FIFO
  thread, so a ``release`` enqueued after a ``put`` can never overtake
  it on a replica); ``put(..., sync=True)`` waits for every copy.
  ``get`` fails over down the successor list when the primary is dead
  (or restarted blank), and refcount ops (``add_ref``/``release``/
  ``delete``) propagate to every replica the same way.  Replica-side
  refcounts are best-effort during membership churn; the surviving
  primary is authoritative and ``rebalance`` re-derives copies from it.
- **Ring rebalancing** (``add_shard``/``remove_shard``/``replace_shard``):
  membership changes recompute the ring and migrate only the keys whose
  replica set actually moved (~1/N of the key space per added shard).
  A spilled key whose source and destination shards share a filesystem
  moves by **renaming its spill file** (`detach_spilled`/`adopt_spilled`
  -- zero payload bytes on the wire); everything else re-puts over the
  frame protocol.  The new ring travels to every shard with a bumped
  ``ring_epoch``; a client still holding the old ring gets a **redirect
  frame** (``{"stale": True, "ring": ...}``) instead of a miss, adopts
  the new ring, and retries -- connected clients converge without any
  out-of-band coordination.
- **Snapshot/restore**: ``snapshot()`` bundles every shard's store (both
  tiers, deduplicated across replicas, sorted -- identical contents give
  identical bytes) into one blob; ``restore()`` re-puts each entry
  through the *current* ring with full-sync replication, so a checkpoint
  taken on one topology restores onto another.  This is what lifts the
  "checkpointing requires inline payloads" restriction:
  ``ColmenaQueues.checkpoint`` captures the Value Server alongside the
  queue fabric and a resumed campaign's restored proxies resolve.
"""
from __future__ import annotations

import atexit
import bisect
import hashlib
import multiprocessing
import os
import pickle
import queue
import tempfile
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple
import uuid

from repro.core.transport import frames, ndcodec

_mp = multiprocessing.get_context("fork")

VS_SNAPSHOT_VERSION = 1

#: shard ops routed by key: these carry the client's ring epoch and are
#: answered with a redirect frame when the client's ring is stale
ROUTED_OPS = frozenset({"vs_put", "vs_get", "vs_add_ref", "vs_release",
                        "vs_delete", "vs_size_of", "vs_contains",
                        "vs_export"})

#: how long a shard holds a ``vs_get`` reply for a key a migration
#: announced but has not delivered (vs_expect without vs_end_expect --
#: the migration manager died); bounds the worst-case client stall
EXPECT_WAIT = 30.0


class HashRing:
    """Consistent-hash ring over *stable shard ids* (md5, virtual nodes).

    ``nodes`` may be an int (ids ``0..n-1``, the original positional
    form) or an explicit id list -- ids survive membership changes, so
    removing shard 1 from ``[0, 1, 2]`` leaves keys homed at 0 and 2
    untouched."""

    def __init__(self, nodes, vnodes: int = 64):
        if isinstance(nodes, int):
            nodes = list(range(nodes))
        self.node_ids = list(nodes)
        points: List[Tuple[int, int]] = []
        for node in self.node_ids:
            for v in range(vnodes):
                h = hashlib.md5(f"shard-{node}:{v}".encode()).digest()
                points.append((int.from_bytes(h[:8], "big"), node))
        points.sort()
        self._hashes = [p[0] for p in points]
        self._nodes = [p[1] for p in points]

    def _pos(self, key: str) -> int:
        h = int.from_bytes(
            hashlib.md5(key.encode()).digest()[:8], "big")
        return bisect.bisect(self._hashes, h) % len(self._hashes)

    def node(self, key: str) -> int:
        return self._nodes[self._pos(key)]

    def nodes(self, key: str, n: int) -> List[int]:
        """The first ``n`` *distinct* shards clockwise from the key's
        ring position -- the key's replica set, primary first.  Walking
        the same ring every client derives from the same member list is
        what makes replica placement agreement total."""
        n = min(n, len(set(self.node_ids)))
        i = self._pos(key)
        out: List[int] = []
        for step in range(len(self._nodes)):
            cand = self._nodes[(i + step) % len(self._nodes)]
            if cand not in out:
                out.append(cand)
                if len(out) == n:
                    break
        return out


# ---------------------------------------------------------------------------
# Shard server process
# ---------------------------------------------------------------------------


def _shard_main(sock, capacity_bytes: Optional[int], spill_dir: Optional[str],
                fetch_bandwidth: Optional[float]) -> None:
    from repro.core.value_server import ValueServer
    vs = ValueServer(capacity_bytes=capacity_bytes, spill_dir=spill_dir,
                     fetch_bandwidth=fetch_bandwidth)
    # the ring this shard believes is current ({"epoch", "members",
    # "replicas"}), pushed by whoever drives membership (owner client or
    # cluster launcher).  None = pre-ring deployment: no staleness checks.
    state = {"ring": None}
    # keys a migration has announced as incoming (``vs_expect``): a get
    # for one of them holds its reply until the copy lands or the
    # migration window closes, instead of answering a transient miss
    # that a replicas=1 deployment has no fallback for.  serve_forever
    # is thread-per-connection, so a held reply blocks only its caller.
    expect = {"keys": set(), "epoch": -1}
    expect_cond = threading.Condition()

    def _landed(key) -> None:
        with expect_cond:
            if key in expect["keys"]:
                expect["keys"].discard(key)
                expect_cond.notify_all()

    def handle(header: dict, payload: bytes):
        op = header["op"]
        ring = state["ring"]
        epoch = header.get("epoch")
        if (ring is not None and epoch is not None and op in ROUTED_OPS
                and epoch < ring["epoch"]):
            # the client routed this with an outdated ring: hand it the
            # current one instead of a wrong-shard miss (or worse, a
            # write landing outside the key's replica set)
            return {"stale": True, "ring": ring}, b""
        if op == "vs_put":
            # stored as the client's pickle bytes: never re-pickled here
            key = vs.put(payload, size=header["size"], refs=header["refs"],
                         key=header["key"])
            _landed(key)
            return {"key": key}, b""
        if op == "vs_get":
            key = header["key"]
            while True:
                try:
                    return {"ok": True}, vs.get(key)
                except KeyError:
                    with expect_cond:
                        if key not in expect["keys"]:
                            return {"ok": False}, b""
                        if not expect_cond.wait(timeout=EXPECT_WAIT):
                            # window never closed (migration manager
                            # died pre-end_expect): stop holding gets
                            expect["keys"].discard(key)
                            return {"ok": False}, b""
        if op == "vs_add_ref":
            vs.add_ref(header["key"])
            return {"ok": True}, b""
        if op == "vs_release":
            return {"deleted": vs.release(header["key"])}, b""
        if op == "vs_delete":
            vs.delete(header["key"])
            return {"ok": True}, b""
        if op == "vs_size_of":
            try:
                return {"size": vs.size_of(header["key"])}, b""
            except KeyError:
                return {"size": None}, b""
        if op == "vs_contains":
            return {"in": header["key"] in vs}, b""
        if op == "vs_export":
            # migration source: the stored bytes plus the metadata the
            # destination's vs_put needs (refs travel with the copy).
            # peek, not get: exporting must not fault a spilled entry
            # into memory (evicting others / deleting its disk copy)
            try:
                data, size, refs = vs.peek(header["key"])
            except KeyError:
                return {"ok": False}, b""
            return {"ok": True, "size": size, "refs": refs}, data
        if op == "vs_keys":
            return {"keys": vs.keys_info()}, b""
        if op == "vs_detach_spill":
            try:
                size, refs = vs.detach_spilled(header["key"])
            except KeyError:
                return {"ok": False}, b""
            return {"ok": True, "size": size, "refs": refs}, b""
        if op == "vs_adopt_spill":
            vs.adopt_spilled(header["key"], header["size"], header["refs"])
            _landed(header["key"])
            return {"ok": True}, b""
        if op == "vs_expect":
            # migration preamble, sent BEFORE the ring push: these keys
            # are on their way here.  Epoch-guarded set union, so a
            # replayed announcement (or one racing a newer migration)
            # converges instead of resurrecting a closed window.
            with expect_cond:
                if header["epoch"] >= expect["epoch"]:
                    expect["epoch"] = header["epoch"]
                    expect["keys"].update(header["keys"])
            return {"ok": True}, b""
        if op == "vs_end_expect":
            # migration postamble (finally-block): whatever did not land
            # is not coming -- release every held get to answer its miss
            with expect_cond:
                if header["epoch"] >= expect["epoch"]:
                    expect["keys"].clear()
                expect_cond.notify_all()
            return {"ok": True}, b""
        if op == "vs_ring":
            return {"ring": state["ring"]}, b""
        if op == "vs_set_ring":
            new = header["ring"]
            cur = state["ring"]
            if cur is None or new["epoch"] >= cur["epoch"]:
                state["ring"] = new
            return {"ok": True, "epoch": state["ring"]["epoch"]}, b""
        if op == "vs_snapshot":
            return {"ok": True}, vs.snapshot()
        # (no per-shard restore op: ShardedValueServer.restore re-puts
        # through the ring so copies land replicated at current homes --
        # a shard-local restore would bypass both)
        if op == "vs_stats":
            return {"stats": dict(vs.stats), "len": len(vs),
                    "bytes": vs.total_bytes,
                    "spilled_bytes": vs.spilled_bytes}, b""
        if op == "ping":
            return {"ok": True}, b""
        if op == "shutdown":
            return None
        return {"error": f"unknown op {op!r}"}, b""

    frames.serve_forever(sock, handle, threading.Event())


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------


class ShardedValueServer:
    """Drop-in ValueServer client routing keys to shard processes.

    ``capacity_bytes`` is **per shard**; with ``spill=True`` each shard
    gets its own spill directory under a shared temp root, so the
    aggregate working set is ``num_shards * capacity_bytes`` in memory
    plus unbounded disk.  ``replicas=R`` stores every key on its R ring
    successors (see module docstring); ``len()`` and the byte totals
    count stored *copies*, so they scale with R."""

    def __init__(self, num_shards: int = 2, *,
                 capacity_bytes: Optional[int] = None,
                 spill: bool = False,
                 fetch_bandwidth: Optional[float] = None,
                 vnodes: int = 64,
                 replicas: int = 1,
                 array_codec: bool = True):
        assert num_shards >= 1
        assert 1 <= replicas
        self.replicas = replicas
        self.array_codec = array_codec
        self.vnodes = vnodes
        self._dir = tempfile.mkdtemp(prefix="colmena-vs-")
        self._owner_pid = os.getpid()
        self._capacity_bytes = capacity_bytes
        self._spill = spill
        self._fetch_bandwidth = fetch_bandwidth
        self._procs: Dict[int, _mp.Process] = {}
        self._clients: Dict[int, frames.FrameClient] = {}
        self._spill_dirs: Dict[int, Optional[str]] = {}
        self._init_client_state()
        members = [(i, self._spawn(i)) for i in range(num_shards)]
        self._install_ring(members, 1)
        self._push_ring(members)
        atexit.register(self.shutdown)

    def _init_client_state(self) -> None:
        self._meta_lock = threading.RLock()
        self._resolver: Optional[ThreadPoolExecutor] = None
        self._resolver_pid = None
        self._repl_q = None
        self._repl_pid = None
        # client-side durability counters (per process)
        self.client_stats = {"failovers": 0, "replica_reads": 0,
                             "redirects": 0, "repl_errors": 0,
                             "repl_stale_drops": 0, "migrate_renames": 0,
                             "migrate_reputs": 0, "migrated_keys": 0}

    @classmethod
    def connect(cls, addresses: List[tuple], vnodes: int = 64,
                replicas: Optional[int] = None,
                array_codec: bool = True) -> "ShardedValueServer":
        """Attach to already-running shard processes (a cluster
        launcher's) instead of spawning them.  The client first asks the
        shards for the current ring (``vs_ring``): if one was pushed
        (epoch, stable ids, replica factor), every connected client
        adopts the *same* membership regardless of the order its address
        list came in.  Pre-ring shards fall back to the positional rule:
        every client must then pass the same ordered address list.
        ``shutdown`` on a connected client is a no-op -- the launcher
        owns the shard processes."""
        assert addresses, "connect() needs at least one shard address"
        self = cls.__new__(cls)
        self.array_codec = array_codec
        self.vnodes = vnodes
        self._dir = None
        self._owner_pid = None              # not ours to shut down
        self._capacity_bytes = None
        self._spill = False
        self._fetch_bandwidth = None
        self._procs = {}
        self._clients = {}
        self._spill_dirs = {}
        self._init_client_state()
        ring = None
        for a in addresses:
            try:
                header, _ = frames.FrameClient(tuple(a)).request(
                    {"op": "vs_ring"}, retry=True)
            except (ConnectionError, OSError):
                continue                    # dead shard: ask the next one
            ring = header.get("ring")
            if ring is not None:
                break
            # reachable but ringless (e.g. a replacement forked just
            # before the rebalance pushed): keep asking -- adopting the
            # positional fallback while a pushed ring exists elsewhere
            # would route this client differently from every other one
        if ring is not None:
            self.replicas = (replicas if replicas is not None
                             else ring.get("replicas", 1))
            self._install_ring([(sid, tuple(ad))
                                for sid, ad in ring["members"]],
                               ring["epoch"])
        else:
            self.replicas = replicas or 1
            self._install_ring([(i, tuple(a))
                                for i, a in enumerate(addresses)], 0)
        return self

    # -- membership plumbing --------------------------------------------------

    def _spawn(self, sid: int) -> tuple:
        """Fork one shard process (owner mode only); returns its address."""
        sock, address = frames.make_server_socket(
            os.path.join(self._dir, f"shard{sid}.sock"))
        spill_dir = (os.path.join(self._dir, f"spill{sid}")
                     if self._spill else None)
        p = _mp.Process(target=_shard_main,
                        args=(sock, self._capacity_bytes, spill_dir,
                              self._fetch_bandwidth),
                        daemon=True, name=f"colmena-vs-shard{sid}")
        p.start()
        sock.close()
        self._procs[sid] = p
        self._spill_dirs[sid] = spill_dir
        self._clients[sid] = frames.FrameClient(address)
        return address

    def _install_ring(self, members: List[tuple], epoch: int) -> None:
        """Adopt a membership: (sid, address) list + epoch.  Clients for
        departed members are kept around (a rebalance still drains them;
        they are closed at shutdown)."""
        with self._meta_lock:
            self._members = [(sid, tuple(addr)) for sid, addr in members]
            self._epoch = epoch
            self._ring = HashRing([sid for sid, _ in self._members],
                                  vnodes=self.vnodes)
            for sid, addr in self._members:
                cur = self._clients.get(sid)
                if cur is None or tuple(cur.address) != addr:
                    # also replaces a client whose sid was *reused* at a
                    # new address (remove then add): keeping the stale
                    # FrameClient would dial a dead socket forever
                    if cur is not None:
                        cur.close()
                    self._clients[sid] = frames.FrameClient(addr)
            self.num_shards = len(self._members)

    def _ring_message(self) -> dict:
        with self._meta_lock:
            return {"epoch": self._epoch,
                    "members": list(self._members),
                    "replicas": self.replicas}

    def _push_ring(self, targets: List[tuple]) -> None:
        """Install the current ring on every reachable shard in
        ``targets`` ((sid, addr) pairs) so stale clients get redirected
        rather than mis-routed."""
        msg = self._ring_message()
        for sid, _ in targets:
            try:
                self._clients[sid].request(
                    {"op": "vs_set_ring", "ring": msg}, retry=True)
            except (ConnectionError, OSError):
                pass                        # dead shard: nothing to redirect

    def _adopt(self, ring: dict) -> None:
        """Apply a redirect frame's ring (newer epochs only)."""
        with self._meta_lock:
            if ring["epoch"] > self._epoch:
                self.replicas = ring.get("replicas", self.replicas)
                self._install_ring(ring["members"], ring["epoch"])
                self.client_stats["redirects"] += 1

    def _refresh_ring(self) -> bool:
        """Ask the live membership for a newer ring; True if one was
        adopted.  Redirect frames only arrive from members a request
        *reaches* -- a stale client whose key's whole (old) replica set
        departed would otherwise dial dead sockets forever, so the
        total-unreachability path asks everyone else before giving up."""
        for sid, _ in list(self._members):
            try:
                h, _ = self._clients[sid].request({"op": "vs_ring"})
            except (ConnectionError, OSError, RuntimeError):
                continue
            ring = h.get("ring")
            if ring is not None and ring["epoch"] > self._epoch:
                self._adopt(ring)
                return True
        return False

    def _replica_set(self, key: str) -> List[int]:
        with self._meta_lock:
            return self._ring.nodes(key, min(self.replicas,
                                             len(self._members)))

    def _send(self, sid: int, header: dict, payload: bytes = b"",
              retry: bool = False) -> Tuple[dict, bytes]:
        header = dict(header)
        header["epoch"] = self._epoch
        return self._clients[sid].request(header, payload, retry=retry)

    def shard_of(self, key: str) -> int:
        return self._replica_set(key)[0]

    # -- background replication (FIFO: ops on one key cannot reorder) --------

    def _repl_queue(self) -> "queue.SimpleQueue":
        # per-process, like the resolver: a forked worker builds its own.
        # Creation is guarded: two threads racing the lazy init would
        # split the fan-out across two FIFOs, and a release drained from
        # one queue could overtake its put waiting in the other
        with self._meta_lock:
            if self._repl_q is None or self._repl_pid != os.getpid():
                self._repl_q = queue.SimpleQueue()
                self._repl_pid = os.getpid()
                threading.Thread(target=self._repl_loop,
                                 args=(self._repl_q,),
                                 daemon=True, name="vs-repl").start()
            return self._repl_q

    def _repl_loop(self, q) -> None:
        while True:
            item = q.get()
            if item is None:
                return                      # close/shutdown sentinel
            if isinstance(item, threading.Event):
                item.set()                  # flush_replication barrier
                continue
            sid, header, payload = item
            try:
                h, _ = self._send(sid, header, payload)
                if h.get("stale"):
                    # membership changed under the queued op: adopt the
                    # ring and let rebalance re-derive the copy (re-fanning
                    # a release here could double-apply it)
                    self._adopt(h["ring"])
                    self.client_stats["repl_stale_drops"] += 1
            except (ConnectionError, OSError, RuntimeError):
                self.client_stats["repl_errors"] += 1

    def _repl_enqueue(self, sid: int, header: dict,
                      payload: bytes = b"") -> None:
        self._repl_queue().put((sid, header, payload))

    def flush_replication(self, timeout: float = 30.0) -> bool:
        """Barrier: wait until every queued replica op has been applied
        (or failed).  ``snapshot`` and ``rebalance`` call this so they
        observe settled replicas; tests use it for determinism."""
        if self._repl_q is None or self._repl_pid != os.getpid():
            return True
        ev = threading.Event()
        self._repl_q.put(ev)
        return ev.wait(timeout)

    # -- ValueServer API ------------------------------------------------------

    def put(self, value, *, size: Optional[int] = None, refs: int = 0,
            sync: bool = False) -> str:
        # dense arrays (numpy / jax device arrays) take the typed codec
        # path: raw buffer behind a dtype/shape header, never a pickle
        # of the array body (ndcodec module docstring).  Everything else
        # pickles as before; the formats self-describe, so readers need
        # no flag agreement with the writer.
        data = ndcodec.encode(value) if self.array_codec else None
        if data is None:
            data = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        if size is None:
            size = len(data)
        # key is minted client-side so routing needs no coordination; the
        # shard adopts it verbatim
        key = uuid.uuid4().hex
        self._put_bytes(key, data, size, refs, sync=sync)
        return key

    def _write_op(self, key: str, header: dict, payload: bytes = b"",
                  sync: bool = False, retry: bool = False) -> dict:
        """Primary-ack write loop shared by put and the refcount ops
        (the write-side sibling of ``_read_op``): the first live shard
        of the replica set that can apply the op acknowledges
        synchronously -- a dead successor fails over to the next, and so
        does one that answers with a server-side error (a blank restarted
        primary raising KeyError for ``add_ref`` must not shadow a
        replica that holds the copy; the error is re-raised only when NO
        replica could apply, preserving single-shard semantics).  The
        remaining copies fan out asynchronously in replication-queue
        order, or inline with ``sync=True`` -- where a stale-ring
        redirect re-runs the whole fan-out (idempotent) rather than
        silently under-replicating a "full-sync" write.  ``retry``
        reconnect-and-resends dropped sockets (idempotent ops only)."""
        for _ in range(4):
            targets = self._replica_set(key)
            resp = None
            rest: List[int] = []
            stale = None
            last_err = None
            for sid in targets:
                if resp is not None:
                    rest.append(sid)
                    continue
                try:
                    h, _ = self._send(sid, header, payload, retry=retry)
                except (ConnectionError, OSError):
                    self.client_stats["failovers"] += 1
                    continue                # dead successor: next one acks
                except RuntimeError as e:
                    last_err = e            # alive but cannot apply
                    self.client_stats["failovers"] += 1
                    continue
                if h.get("stale"):
                    stale = h["ring"]
                    break
                resp = h
            if stale is not None:
                self._adopt(stale)
                continue
            if resp is None:
                if last_err is not None:
                    raise last_err
                if self._refresh_ring():    # see _read_op: stale set dead
                    continue
                raise ConnectionError(
                    f"every replica of key {key!r} is unreachable")
            for sid in rest:
                if sync:
                    try:
                        h, _ = self._send(sid, header, payload)
                    except (ConnectionError, OSError, RuntimeError):
                        self.client_stats["repl_errors"] += 1
                        continue
                    if h.get("stale"):
                        stale = h["ring"]
                        break
                else:
                    self._repl_enqueue(sid, header, payload)
            if stale is not None:
                self._adopt(stale)
                continue
            return resp
        raise RuntimeError("ring membership kept changing during "
                           + header["op"])

    def _put_bytes(self, key: str, data: bytes, size: int, refs: int,
                   sync: bool = False) -> None:
        self._write_op(key, {"op": "vs_put", "key": key, "size": size,
                             "refs": refs}, data, sync=sync)

    _MISS = object()                        # sentinel: replica can't answer

    def _read_op(self, key: str, header: dict, hit):
        """Shared read-side failover loop (get / size_of / contains):
        walk the key's replica set in order, failing over past dead
        shards, adopting stale-ring redirects and retrying (max 4
        membership changes), raising ConnectionError when no replica is
        reachable and KeyError when every live replica misses.  A miss
        on one replica is never authoritative -- a restarted (blank)
        primary must not shadow a live replica's copy.  ``hit(resp,
        payload, i)`` extracts the answer or returns ``_MISS``."""
        for _ in range(4):
            stale = None
            alive = 0
            for i, sid in enumerate(self._replica_set(key)):
                try:
                    # fabriclint: retry-ops=vs_get,vs_size_of,vs_contains
                    h, payload = self._send(sid, header, retry=True)
                except (ConnectionError, OSError):
                    self.client_stats["failovers"] += 1
                    continue                # dead replica: try the next
                if h.get("stale"):
                    stale = h["ring"]
                    break
                alive += 1
                out = hit(h, payload, i)
                if out is not self._MISS:
                    return out
            if stale is not None:
                self._adopt(stale)
                continue
            if alive == 0:
                # the whole (possibly stale) replica set is dead: a
                # membership change may have moved the key -- learn the
                # current ring from any live member before giving up
                if self._refresh_ring():
                    continue
                raise ConnectionError(
                    f"every replica of key {key!r} is unreachable")
            raise KeyError(key)
        raise RuntimeError("ring membership kept changing during "
                           + header["op"])

    def get(self, key: str):
        # ndcodec.decode falls through to pickle.loads for plain
        # pickles, so a codec-off writer and codec-on reader (or the
        # reverse) always interoperate
        return ndcodec.decode(self._get_bytes(key))

    def _get_bytes(self, key: str) -> bytes:
        def hit(h, payload, i):
            if not h["ok"]:
                return self._MISS
            if i > 0:
                self.client_stats["replica_reads"] += 1
            return payload

        return self._read_op(key, {"op": "vs_get", "key": key}, hit)

    def add_ref(self, key: str) -> None:
        self._write_op(key, {"op": "vs_add_ref", "key": key})

    def release(self, key: str) -> bool:
        return self._write_op(
            key, {"op": "vs_release", "key": key})["deleted"]

    def delete(self, key: str) -> None:
        self._write_op(key, {"op": "vs_delete", "key": key}, retry=True)

    def size_of(self, key: str) -> int:
        return self._read_op(
            key, {"op": "vs_size_of", "key": key},
            lambda h, _p, _i: h["size"] if h["size"] is not None
            else self._MISS)

    def __contains__(self, key: str) -> bool:
        # every-live-replica-misses is a definitive "absent" here (the
        # KeyError becomes False); an unreachable replica set still
        # raises ConnectionError -- an outage is not evidence of
        # deletion, and a False could make a caller drop or resubmit a
        # payload that survived
        try:
            return self._read_op(
                key, {"op": "vs_contains", "key": key},
                lambda h, _p, _i: True if h["in"] else self._MISS)
        except KeyError:
            return False

    def prefetch(self, key: str) -> Future:
        # the executor is per-process: a forked worker lazily builds its
        # own.  Guarded like _repl_queue -- two racing prefetch calls must
        # not each build an executor (the loser's 4 threads would leak)
        with self._meta_lock:
            if self._resolver is None or self._resolver_pid != os.getpid():
                self._resolver = ThreadPoolExecutor(
                    max_workers=4, thread_name_prefix="vs-resolve")
                self._resolver_pid = os.getpid()
            resolver = self._resolver
        return resolver.submit(self.get, key)

    # -- membership changes / rebalancing -------------------------------------

    def add_shard(self, address: Optional[tuple] = None) -> Tuple[int, int]:
        """Grow the ring by one shard: spawn a process (owner mode,
        ``address=None``) or adopt an externally started one.  Returns
        ``(new_sid, keys_migrated)`` -- the consistent ring bounds the
        migration to ~1/N of the key space."""
        with self._meta_lock:
            new_sid = max(sid for sid, _ in self._members) + 1
            if address is None:
                assert self._dir is not None, \
                    "a connected client adds externally started shards: " \
                    "pass address="
                address = self._spawn(new_sid)
            else:
                address = tuple(address)
                self._clients[new_sid] = frames.FrameClient(address)
            new_members = self._members + [(new_sid, address)]
        moved = self._rebalance(new_members)
        return new_sid, moved

    def remove_shard(self, sid: int) -> int:
        """Shrink the ring: drain the shard's keys to their new homes
        (when it is still reachable -- a dead shard's keys are re-derived
        from replicas), then drop it from membership.  Owner mode also
        stops the process.  Returns the number of keys migrated."""
        with self._meta_lock:
            new_members = [m for m in self._members if m[0] != sid]
            assert new_members, "cannot remove the last shard"
        unreachable = set() if self._probe(sid) else {sid}
        moved = self._rebalance(new_members, unreachable=unreachable)
        self._stop_shard(sid)
        return moved

    def replace_shard(self, dead_sid: int,
                      address: Optional[tuple] = None) -> int:
        """Swap a (typically dead) shard for a fresh one in a single
        rebalance: the replacement joins the ring, lost copies are
        re-replicated from survivors, and the dead member leaves.
        Returns the new shard's sid."""
        with self._meta_lock:
            new_sid = max(sid for sid, _ in self._members) + 1
            if address is None:
                assert self._dir is not None, \
                    "a connected client replaces with an externally " \
                    "started shard: pass address="
                address = self._spawn(new_sid)
            else:
                address = tuple(address)
                self._clients[new_sid] = frames.FrameClient(address)
            new_members = ([m for m in self._members if m[0] != dead_sid]
                           + [(new_sid, address)])
        unreachable = set() if self._probe(dead_sid) else {dead_sid}
        self._rebalance(new_members, unreachable=unreachable)
        self._stop_shard(dead_sid)
        return new_sid

    def _probe(self, sid: int) -> bool:
        client = self._clients.get(sid)
        if client is None:
            return False
        return client.probe()

    def _stop_shard(self, sid: int) -> None:
        p = self._procs.pop(sid, None)
        if p is None:
            return
        try:
            self._clients[sid].request({"op": "shutdown"})
        except (ConnectionError, OSError):
            pass
        p.join(timeout=2)
        if p.is_alive():
            p.terminate()

    def terminate_shard(self, sid: int) -> None:
        """Chaos helper (owner mode): SIGKILL one shard process -- the
        node-loss failure the replication/failover paths exist for."""
        p = self._procs.get(sid)
        assert p is not None, f"shard {sid} is not owned by this client"
        p.kill()
        p.join(timeout=2)

    def _rebalance(self, new_members: List[tuple],
                   unreachable: frozenset = frozenset()) -> int:
        """Adopt ``new_members``, push the bumped ring to every shard,
        and migrate exactly the copies whose replica set changed.

        Ordering: the old members are inventoried and every receiving
        shard is told which keys are incoming (``vs_expect``) *before*
        the bumped ring is pushed and any data moves -- so from the very
        first frame a redirected client can route by the new ring, a
        mid-move ``get`` of a not-yet-landed key **blocks at its new
        home until the copy arrives** instead of answering a transient
        miss (which a replicas=1 deployment has no replica to absorb).
        The expect window is closed in a ``finally`` (``vs_end_expect``)
        so keys whose transfer failed answer their miss instead of
        stalling gets until the shard-side timeout.  Concurrent *puts*
        remain subject to the quiesced-point caveat: a put landing on a
        departing member between the inventory and the ring push is
        invisible to this migration (campaigns drive membership changes
        from launcher restart / resume, where no puts are in flight)."""
        self.flush_replication()
        with self._meta_lock:
            old_members = list(self._members)
            self._install_ring(new_members, self._epoch + 1)
            epoch = self._epoch
            push_targets = {sid: addr for sid, addr in old_members}
            push_targets.update(dict(self._members))
        # inventory: key -> holders (replicas disagree only transiently;
        # refs take the max so a pinned copy can never lose its pin)
        holders: Dict[str, dict] = {}
        for sid, _ in old_members:
            if sid in unreachable:
                continue
            try:
                h, _ = self._send(sid, {"op": "vs_keys"}, retry=True)
            except (ConnectionError, OSError):
                continue
            for key, size, refs, tier in h["keys"]:
                info = holders.setdefault(
                    key, {"size": size, "refs": refs, "tiers": {}})
                info["refs"] = max(info["refs"], refs)
                info["tiers"][sid] = tier
        R = min(self.replicas, len(new_members))
        incoming: Dict[int, set] = {}
        for key, info in holders.items():
            for dst in self._ring.nodes(key, R):
                if dst not in info["tiers"]:
                    incoming.setdefault(dst, set()).add(key)
        announced: List[int] = []
        for dst in sorted(incoming):
            try:
                self._send(dst, {"op": "vs_expect", "epoch": epoch,
                                 "keys": sorted(incoming[dst])},
                           retry=True)
                announced.append(dst)
            except (ConnectionError, OSError, RuntimeError):
                pass                # unreachable dst: transfers fail too
        moved = 0
        try:
            self._push_ring(sorted(push_targets.items()))
            for key, info in holders.items():
                new_set = self._ring.nodes(key, R)
                have = info["tiers"]
                placed = sum(1 for s in new_set if s in have)
                for dst in new_set:
                    if dst in have:
                        continue
                    src = next((s for s in new_set if s in have),
                               next(iter(have)))
                    if self._transfer(key, src, dst, info["size"],
                                      info["refs"], have[src]):
                        moved += 1
                        placed += 1
                if placed == 0:
                    # every transfer into the new replica set failed
                    # (e.g. the new home is momentarily unreachable):
                    # deleting the departing copies now would destroy
                    # the key's ONLY copies -- leave them where they
                    # are; a later rebalance re-derives placement from
                    # the surviving holders
                    continue
                for sid in set(have) - set(new_set):
                    try:
                        self._send(sid, {"op": "vs_delete", "key": key})
                    except (ConnectionError, OSError):
                        pass
        finally:
            for dst in announced:
                try:
                    self._send(dst, {"op": "vs_end_expect",
                                     "epoch": epoch}, retry=True)
                except (ConnectionError, OSError, RuntimeError):
                    pass
        self.client_stats["migrated_keys"] += moved
        return moved

    def _transfer(self, key: str, src: int, dst: int, size: int, refs: int,
                  tier: str) -> bool:
        """Move one copy.  Spill-tier fast path: when both shards'
        spill dirs are co-located (owner mode), the spill file is
        *renamed* into the destination and adopted -- no payload bytes
        cross a socket.  Otherwise the copy re-puts over the frame
        protocol."""
        src_dir = self._spill_dirs.get(src)
        dst_dir = self._spill_dirs.get(dst)
        if tier == "spill" and src_dir and dst_dir:
            src_path = os.path.join(src_dir, key + ".pkl")
            dst_path = os.path.join(dst_dir, key + ".pkl")
            detached = False
            try:
                h, _ = self._send(src, {"op": "vs_detach_spill", "key": key})
                if h.get("ok"):
                    detached = True
                    os.rename(src_path, dst_path)
                    self._send(dst, {"op": "vs_adopt_spill", "key": key,
                                     "size": h["size"], "refs": h["refs"]})
                    self.client_stats["migrate_renames"] += 1
                    return True
            except (ConnectionError, OSError, RuntimeError):
                # a detached-but-not-adopted key is registered NOWHERE: it
                # must be re-attached at the source before the re-put
                # fallback, or a replicas=1 migration would lose its only
                # copy (the file would sit orphaned on disk forever)
                if detached:
                    try:
                        if os.path.exists(dst_path):
                            os.rename(dst_path, src_path)
                        self._send(src, {"op": "vs_adopt_spill", "key": key,
                                         "size": h["size"],
                                         "refs": h["refs"]})
                    except (ConnectionError, OSError, RuntimeError):
                        return False        # source gone too: unrecoverable
        try:
            h, payload = self._send(src, {"op": "vs_export", "key": key},
                                    retry=True)
            if not h.get("ok"):
                return False
            h2, _ = self._send(dst, {"op": "vs_put", "key": key,
                                     "size": h["size"], "refs": refs},
                               payload)
            if "key" not in h2:
                # a stale-ring redirect (another manager raced this
                # rebalance): the copy was NOT stored -- counting it
                # would let the caller delete the only real copies
                return False
        except (ConnectionError, OSError, RuntimeError):
            return False
        self.client_stats["migrate_reputs"] += 1
        return True

    # -- snapshot / restore ---------------------------------------------------

    def snapshot(self) -> bytes:
        """One deterministic blob for the whole ring: every shard's
        store (both tiers), deduplicated across replicas (max refs wins
        -- a lagging replica can never strip a pin), sorted by key.  A
        dead shard contributes nothing *only when the replica factor
        covers it*: with ``replicas`` unreachable members the missing
        keys could have no surviving copy, and writing that image would
        atomically overwrite the last complete checkpoint with a
        silently incomplete one -- so that raises instead."""
        self.flush_replication()
        entries: Dict[str, tuple] = {}
        unreachable = []
        for sid, _ in self._members:
            try:
                _, blob = self._send(sid, {"op": "vs_snapshot"}, retry=True)
            except (ConnectionError, OSError):
                unreachable.append(sid)
                continue
            for key, data, size, refs in pickle.loads(blob)["entries"]:
                cur = entries.get(key)
                if cur is None or refs > cur[3]:
                    entries[key] = (key, data, size, refs)
        if len(unreachable) >= self.replicas:
            raise ConnectionError(
                f"shards {unreachable} unreachable with replicas="
                f"{self.replicas}: a snapshot taken now could be missing"
                " keys with no surviving copy -- refusing to write an"
                " incomplete checkpoint")
        return pickle.dumps(
            {"version": VS_SNAPSHOT_VERSION, "sharded": True,
             "entries": [entries[k] for k in sorted(entries)]},
            protocol=pickle.HIGHEST_PROTOCOL)

    def restore(self, data: bytes) -> int:
        """Re-put every snapshot entry through the *current* ring with
        full-sync replication -- the restoring topology may have a
        different shard count or replica factor than the one that took
        the snapshot.  A plain (in-process) ValueServer snapshot is
        accepted too: its entry values are live objects and get pickled
        on the way in, so a local-backend checkpoint restores onto a
        sharded deployment."""
        state = pickle.loads(data)
        if state.get("version") != VS_SNAPSHOT_VERSION:
            raise ValueError("unsupported value-server snapshot version "
                             f"{state.get('version')!r}")
        sharded = state.get("sharded", False)
        for key, blob, size, refs in state["entries"]:
            if not sharded:
                blob = pickle.dumps(blob, protocol=pickle.HIGHEST_PROTOCOL)
            self._put_bytes(key, blob, size, refs, sync=True)
        return len(state["entries"])

    # -- introspection --------------------------------------------------------

    def per_shard_stats(self) -> List[dict]:
        out = []
        for sid, _ in self._members:
            try:
                header, _ = self._send(sid, {"op": "vs_stats"}, retry=True)
            except (ConnectionError, OSError):
                # introspection must tolerate the node-loss states the
                # data path fails over through: a dead member reports
                # zeros (flagged), it doesn't crash monitoring code
                out.append({"sid": sid, "dead": True, "len": 0,
                            "bytes": 0, "spilled_bytes": 0})
                continue
            out.append({"sid": sid, "len": header["len"],
                        "bytes": header["bytes"],
                        "spilled_bytes": header["spilled_bytes"],
                        **header["stats"]})
        return out

    @property
    def stats(self) -> Dict[str, int]:
        # aggregate only the counters the in-process ValueServer.stats has
        # (len/bytes/spilled_bytes live on their own properties), keeping
        # the drop-in key set identical across deployments
        agg: Dict[str, int] = {}
        for s in self.per_shard_stats():
            for k, v in s.items():
                if k in ("sid", "dead", "len", "bytes", "spilled_bytes"):
                    continue
                agg[k] = agg.get(k, 0) + v
        return agg

    def __len__(self) -> int:
        return sum(s["len"] for s in self.per_shard_stats())

    @property
    def total_bytes(self) -> int:
        return sum(s["bytes"] for s in self.per_shard_stats())

    @property
    def spilled_bytes(self) -> int:
        return sum(s["spilled_bytes"] for s in self.per_shard_stats())

    def _stop_repl_thread(self) -> None:
        """Drain-and-stop the background replication thread (queued ops
        apply first -- the sentinel is FIFO behind them).  Without this,
        every client that ever fanned out an async op leaks a daemon
        thread parked on ``q.get()`` that pins the whole object alive."""
        with self._meta_lock:
            q, self._repl_q = self._repl_q, None
            pid, self._repl_pid = self._repl_pid, None
        if q is not None and pid == os.getpid():
            q.put(None)

    def close(self) -> None:
        """Close this client's sockets and stop its replication thread
        (shard processes untouched) -- the counterpart of ``connect``
        for short-lived management clients; owner clients use
        ``shutdown``."""
        self._stop_repl_thread()
        for c in self._clients.values():
            c.close()

    def shutdown(self) -> None:
        if os.getpid() != self._owner_pid or not self._procs:
            return
        self._stop_repl_thread()
        procs, self._procs = dict(self._procs), {}
        for sid, p in procs.items():
            try:
                self._clients[sid].request({"op": "shutdown"})
            except (ConnectionError, OSError):
                pass
        for c in self._clients.values():
            c.close()
        for p in procs.values():
            p.join(timeout=2)
            if p.is_alive():
                p.terminate()
        import shutil
        shutil.rmtree(self._dir, ignore_errors=True)
