"""Pluggable transport fabric: how Colmena messages cross process boundaries.

The paper runs Thinker, Task Server, and the Redis queue/value store as
*separate processes* spanning nodes (§III, Fig. 2); everything above this
package (``ColmenaQueues``, Task Servers, Thinkers) is transport-agnostic
and selects a backend by name:

- ``local``  -- today's in-process fabric: per-topic ``Condition``-notified
  deques (the PR-1 ``_WakeQueue``), zero-copy envelopes, no sockets.
- ``proc``   -- a stdlib-only socket fabric: a **broker process** owns every
  per-topic request/result queue and serves them over a Unix-domain socket
  (TCP fallback) to any number of client processes.

Both backends implement the same two-method surface: ``Transport.channel
(topic, kind)`` returns a ``Channel`` with ``put`` / ``get_batch`` /
``wake`` exactly mirroring the in-process queue semantics (blocking
consumers, batched drains, ``wake_all`` for shutdown).

Frame protocol (``proc`` backend)
---------------------------------
Every request and response is one length-prefixed frame::

    uint32 header_len | header (pickle of a small dict) | payload bytes

The header carries the op ("put", "get", "wake", "claim", "vs_*", ...) and
its small arguments (topic, kind, timeouts, metadata); the payload is the
message's **already-pickled** envelope bytes, appended verbatim.  The
broker never unpickles a payload -- the single pickle paid by the sender
*is* the wire format, so serialization still happens exactly once per hop
(the envelope meta that used to ride a NamedTuple rides the frame header).

Blocking semantics are preserved on the wire: a ``get`` request parks a
per-connection handler thread on the broker's queue Condition until items
arrive, a ``wake`` bumps the wake epoch (releasing every parked getter so
cancel events propagate), or the client-supplied timeout lapses -- the
client simply blocks in ``recv`` with no polling loop on either side.
Batched drains survive too: one ``get`` frame can return up to ``max_n``
envelopes concatenated in a single response payload.

Delivery is **leased** (exactly-once dispatch), on both backends: a
``get`` moves its envelopes to an in-flight ledger under a lease id
instead of destroying them, consumers ``ack`` once the batch is safely
handed off (acks piggyback on the next outgoing frame, so the hot path
stays one round-trip), and an unacked lease -- consumer SIGKILL, dropped
response frame -- expires and requeues its envelopes for redelivery.
Publishers that must be exactly-once fuse an atomic first-completion
claim into the enqueue (``put(env, claim=task_id)``), so a redelivery
racing a slow-but-alive original yields exactly one published result.
``Transport.snapshot()/restore()`` serialize the whole fabric state
(queued + leased envelopes, claim window, wake epochs) as one consistent
cut -- the substrate of ``ColmenaQueues.checkpoint``/``resume`` and
campaign-level restart without resubmission.

Control plane vs data plane
---------------------------
The fabric splits who *supervises* work from who *moves* its bytes.

**Data plane** -- envelope bytes take the shortest path that exists:

- **Direct subscription**: every consumer (pool worker, inference
  shard, Thinker) discovers its topic's home broker through the
  ``endpoints`` op (peer map + partition, advertised by every broker of
  a federation) and dials it directly, holding and renewing its *own*
  lease.  In a cluster this removes the per-frame relay hop the
  federation layer used to take for remotely-homed topics -- the relay
  remains only as a correctness fallback for clients that haven't
  discovered yet.
- **Shared-memory lane** (``transport.shm``): between co-located
  processes, a payload >= ``SHM_THRESHOLD`` rides a ``/dev/shm``
  segment; the frame header carries a flat ``{"name", "size"}``
  descriptor and the socket carries no body.  Segment ownership is tied
  to the lease lifecycle (producer until handoff, broker until
  ack/claim-reject, consumers only map and read), so a SIGKILLed
  consumer can neither leak a segment past the broker's registry nor
  double-free it; fabric teardown sweeps the scope.
- **Typed array codec** (``transport.ndcodec``): Value Server payloads
  that are numpy/jax arrays serialize as a self-describing typed header
  plus the raw buffer -- ``pickle`` never touches the array body, and
  decode returns a zero-copy view (re-wrapped on device for jax).

**Control plane** -- supervision stays where the global view is: the
pool parent watches worker liveness and straggler timers (scheduling
backup clones broker-side via the ``backup`` op, with placement
exclusions in envelope meta), the federation coordinator owns
partition/topology, and the launcher owns process lifecycle + the shm
scope sweep.  Control messages are small and infrequent; they never
carry payload bytes.

The same frame protocol serves the sharded Value Server
(``transport.shards``): each ``ValueServerShard`` is a process exposing
put/get/ref ops over its own socket, and clients route keys to shards by
consistent hashing.
"""
from __future__ import annotations

from repro.core.transport.base import Channel, Envelope, Transport  # noqa: F401
from repro.core.transport.local import LocalTransport  # noqa: F401


def make_transport(backend: str = "local", **kwargs) -> Transport:
    """Create a transport backend by name (``local`` or ``proc``)."""
    if backend == "local":
        return LocalTransport(**kwargs)
    if backend == "proc":
        from repro.core.transport.proc import ProcTransport
        return ProcTransport(**kwargs)
    raise ValueError(f"unknown transport backend {backend!r}; "
                     "expected 'local' or 'proc'")
