"""Typed array codec: device/host arrays as raw buffers, not pickles.

The Value Server moves simulation payloads, and for ML-in-the-loop
campaigns those are overwhelmingly dense arrays -- jax device arrays and
numpy ndarrays.  ``pickle.dumps`` of an array detours the body through
pickle's frame machinery (an extra copy, opcode framing, and a
deserialize that reassembles the buffer from pickled chunks).  This
codec writes the body as its raw contiguous buffer behind a tiny typed
header instead::

    b"NDC1" | uint32 header_len (BE) | pickled {dtype, shape, kind} | buffer

Only the *header* dict (three small scalars) is pickled; the array body
is ``tobytes()`` on encode and a zero-copy ``np.frombuffer`` view on
decode.  Device arrays come to the host via ``np.from_dlpack`` where
available (zero-copy on CPU backends), falling back to ``np.asarray``;
``kind == "jax"`` round-trips back to a device array when jax is
importable in the consumer.  Pickle streams (protocol >= 2) always start
with ``b"\\x80"``, so the magic can never be mistaken for one.

``encode`` answers None for anything it does not handle -- object
dtypes, non-arrays -- and callers fall back to pickle; ``decode``
likewise falls through to ``pickle.loads`` for unmagic'd bytes, so
stored values are self-describing and the codec can be toggled per
client without a migration.
"""
from __future__ import annotations

import pickle
import struct
import sys
from typing import Optional

import numpy as np

MAGIC = b"NDC1"
_LEN = struct.Struct(">I")
# the typed header's fixed overhead: magic + length word + a small
# pickled dict; used by sizers that must not pickle the body
HEADER_PAD = 96


def _as_host_array(value):
    """(host_ndarray, kind) for a codec-eligible value, else (None, None).
    jax is recognized only when already imported -- the codec must never
    be the thing that pulls a multi-hundred-MB runtime into a process
    that was not going to use it."""
    if isinstance(value, np.ndarray):
        return value, "np"
    jax = sys.modules.get("jax")
    if jax is not None and isinstance(value, getattr(jax, "Array", ())):
        try:
            host = np.from_dlpack(value)    # zero-copy on CPU backends
        except Exception:                   # noqa: BLE001
            host = np.asarray(value)
        return host, "jax"
    return None, None


def nbytes_of(value) -> Optional[int]:
    """Serialized size of a codec-eligible value without touching
    pickle; None if ``encode`` would decline it.  Lets proxy-threshold
    sizers and store accounting stay pickle-free for arrays."""
    arr, _kind = _as_host_array(value)
    if arr is None or arr.dtype.hasobject:
        return None
    return arr.nbytes + HEADER_PAD


def encode(value) -> Optional[bytes]:
    """The typed wire bytes for an array value, or None to tell the
    caller to pickle (anything that is not a dense non-object array)."""
    arr, kind = _as_host_array(value)
    if arr is None or arr.dtype.hasobject:
        return None
    arr = np.ascontiguousarray(arr)
    head = pickle.dumps({"dtype": arr.dtype.str, "shape": arr.shape,
                         "kind": kind}, protocol=pickle.HIGHEST_PROTOCOL)
    return b"".join((MAGIC, _LEN.pack(len(head)), head,
                     arr.tobytes(order="C")))


def decode(data: bytes):
    """Inverse of ``encode``; plain pickles pass through ``pickle.loads``
    untouched.  The numpy result is a read-only zero-copy view over
    ``data``; ``kind == "jax"`` re-materializes a device array when jax
    is importable here (a consumer without jax still gets the host
    view -- same numbers, host memory)."""
    if not data.startswith(MAGIC):
        return pickle.loads(data)
    off = len(MAGIC) + _LEN.size
    hlen = _LEN.unpack_from(data, len(MAGIC))[0]
    meta = pickle.loads(data[off:off + hlen])
    arr = np.frombuffer(data, dtype=np.dtype(meta["dtype"]),
                        offset=off + hlen).reshape(meta["shape"])
    if meta["kind"] == "jax" and "jax" in sys.modules:
        import jax.numpy as jnp
        return jnp.asarray(arr)
    return arr
