"""Transport interface: channels of single-pickle envelopes.

An ``Envelope`` is what physically traverses a queue hop: the enqueue
timestamp (for queue-transit measurement), the message's single pickle,
and the sender-side measurements the receiver grafts onto the message's
Timer.  Backends differ only in *where* the envelope waits: an in-process
deque (``local``) or a broker process reached over a socket (``proc``).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import List, NamedTuple, Optional


class BoundedIdSet:
    """Insertion-ordered set with a capacity cap (oldest ids age out one
    at a time).  Shared by the Task Server's straggler dedup window and
    both transports' ``claim`` arbitration, so the eviction semantics
    can never drift apart."""

    def __init__(self, maxlen: int):
        self.maxlen = maxlen
        self._order: deque = deque()
        self._set: set = set()

    def add(self, item) -> None:
        if item in self._set:
            return
        self._set.add(item)
        self._order.append(item)
        while len(self._order) > self.maxlen:
            self._set.discard(self._order.popleft())

    def claim(self, item) -> bool:
        """Atomic-within-the-caller's-lock test-and-add: True for exactly
        the first claimant of ``item`` inside the window."""
        if item in self._set:
            return False
        self.add(item)
        return True

    def __contains__(self, item) -> bool:
        return item in self._set

    def __len__(self) -> int:
        return len(self._order)


class Envelope(NamedTuple):
    t_put: float            # enqueue time (queue-transit measurement)
    data: bytes             # the single pickle of the message
    meta: dict              # sender-side measurements grafted on receive


class Channel:
    """One direction of one topic (requests or results)."""

    def put(self, env: Envelope) -> None:
        raise NotImplementedError

    def get(self, timeout: Optional[float] = None,
            cancel: Optional[threading.Event] = None) -> Optional[Envelope]:
        batch = self.get_batch(1, timeout=timeout, cancel=cancel)
        return batch[0] if batch else None

    def get_batch(self, max_n: int, timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[Envelope]:
        raise NotImplementedError

    def wake(self) -> None:
        """Nudge every blocked consumer (shutdown/cancel propagation)."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class Transport:
    """Factory of channels plus fabric-wide control operations."""

    name = "base"

    def channel(self, topic: str, kind: str) -> Channel:
        raise NotImplementedError

    def wake_all(self) -> None:
        raise NotImplementedError

    def claim(self, task_id: str) -> bool:
        """Atomic first-completion claim (straggler-race dedup across
        processes).  Returns True for exactly one claimant per id.  The
        local backend has no cross-process races to arbitrate, so the
        in-process Task Server keeps its own dedup window and this
        default is only used by the process pool."""
        raise NotImplementedError

    def close(self) -> None:
        """Tear down any processes/sockets owned by this transport."""
