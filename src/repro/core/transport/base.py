"""Transport interface: channels of single-pickle envelopes.

An ``Envelope`` is what physically traverses a queue hop: the enqueue
timestamp (for queue-transit measurement), the message's single pickle,
and the sender-side measurements the receiver grafts onto the message's
Timer.  Backends differ only in *where* the envelope waits: an in-process
deque (``local``) or a broker process reached over a socket (``proc``).
"""
from __future__ import annotations

import pickle
import threading
from collections import deque
from typing import List, NamedTuple, Optional

SNAPSHOT_VERSION = 1


def dump_snapshot(queues: list, claims_maxlen: int, claims_order: list,
                  cancelled_maxlen: int = 0, cancelled_order: list = (),
                  ) -> bytes:
    """Shared snapshot wire format for both backends.  ``queues`` is a
    list of ``(topic, kind, epoch, items, leases)`` with ``items`` a list
    of ``(t_put, meta, data)`` and ``leases`` a list of ``(lease_id,
    duration, items)``.  Callers pass queues sorted by (topic, kind) and
    leases sorted by id so identical state always produces identical
    bytes (no wall-clock values are stored).  ``cancelled_*`` carries the
    preemption window: a cancelled id must stay cancelled across
    checkpoint/resume, or a restored stale envelope of a cancelled task
    would re-execute work the Thinker already culled (readers use
    ``state.get("cancelled")`` -- pre-cancel snapshots simply lack it)."""
    state = {"version": SNAPSHOT_VERSION, "queues": queues,
             "claims": {"maxlen": claims_maxlen, "order": claims_order},
             "cancelled": {"maxlen": cancelled_maxlen,
                           "order": list(cancelled_order)}}
    return pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)


def load_snapshot(data: bytes) -> dict:
    state = pickle.loads(data)
    if state.get("version") != SNAPSHOT_VERSION:
        raise ValueError(
            f"unsupported snapshot version {state.get('version')!r}")
    return state


def snapshot_id_sets(state: dict) -> tuple:
    """(all_ids, result_ids, claimed_ids) of a parsed snapshot: every
    task id riding an envelope meta (queued + leased), the subset found
    on ``results``-kind queues, and the claim window.  Building blocks
    of ``derive_active``."""
    all_ids: set = set()
    result_ids: set = set()
    for _topic, kind, _epoch, items, leases in state["queues"]:
        metas = [meta for _t, meta, _d in items]
        for _lid, _dur, lease_items in leases:
            metas.extend(meta for _t, meta, _d in lease_items)
        for meta in metas:
            tid = meta.get("task_id")
            if tid is not None:
                all_ids.add(tid)
                if kind == "results":
                    result_ids.add(tid)
    return all_ids, result_ids, set(state["claims"]["order"])


def derive_active(states: list) -> int:
    """The still-unfinished task count of one or more parsed snapshots
    (a federation contributes one per member; the sets must be unioned
    *before* subtracting, because a stale envelope and the claim that
    obsoletes it can live on different members).  This is how a
    broker-side auto-snapshot, which has no application around to
    record an active count, gets one derived at resume time.

    Not every captured envelope is live work: a worker acks its
    dispatch lease only after publishing (the ack may still be
    piggyback-pending when the snapshot fires), so a snapshot can image
    a lease for a task whose result was already consumed.  Counting it
    would make a resumed ``wait_until_done`` hang forever -- the
    redelivered re-execution loses the restored claim and never
    delivers.  The tell: the id is **claimed but no result envelope is
    queued anywhere** (the claim is fused with the result enqueue, so
    claimed-and-absent means consumed).  Such ids are excluded; their
    stale envelopes redeliver, re-execute, and are swallowed by the
    claim window, exactly as in a live fabric."""
    all_ids: set = set()
    result_ids: set = set()
    claimed: set = set()
    for state in states:
        a, r, c = snapshot_id_sets(state)
        all_ids |= a
        result_ids |= r
        claimed |= c
    return len(all_ids - (claimed - result_ids))


class BoundedIdSet:
    """Insertion-ordered set with a capacity cap (oldest ids age out one
    at a time).  Shared by the Task Server's straggler dedup window and
    both transports' ``claim`` arbitration, so the eviction semantics
    can never drift apart."""

    def __init__(self, maxlen: int):
        self.maxlen = maxlen
        self._order: deque = deque()
        self._set: set = set()

    def add(self, item) -> None:
        if item in self._set:
            return
        self._set.add(item)
        self._order.append(item)
        while len(self._order) > self.maxlen:
            self._set.discard(self._order.popleft())

    def claim(self, item) -> bool:
        """Atomic-within-the-caller's-lock test-and-add: True for exactly
        the first claimant of ``item`` inside the window."""
        if item in self._set:
            return False
        self.add(item)
        return True

    def __contains__(self, item) -> bool:
        return item in self._set

    def __len__(self) -> int:
        return len(self._order)


class BoundedDict:
    """Insertion-ordered dict with BoundedIdSet's sliding-window eviction
    (oldest *keys* age out one at a time past ``maxlen``).  Used where a
    per-task diagnostic map must not grow without bound over a long
    campaign (e.g. the process pool's ``task_history``)."""

    def __init__(self, maxlen: int):
        self.maxlen = maxlen
        self._order: deque = deque()
        self._data: dict = {}

    def _admit(self, key) -> None:
        self._order.append(key)
        while len(self._order) > self.maxlen:
            self._data.pop(self._order.popleft(), None)

    def __setitem__(self, key, value) -> None:
        if key not in self._data:
            self._admit(key)
        self._data[key] = value

    def setdefault(self, key, default):
        if key not in self._data:
            self[key] = default
        return self._data[key]

    def get(self, key, default=None):
        return self._data.get(key, default)

    def __getitem__(self, key):
        return self._data[key]

    def __contains__(self, key) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def keys(self):
        return self._data.keys()

    def items(self):
        return self._data.items()


class Envelope(NamedTuple):
    t_put: float            # enqueue time (queue-transit measurement)
    data: bytes             # the single pickle of the message
    meta: dict              # sender-side measurements grafted on receive


class Channel:
    """One direction of one topic (requests or results).

    Delivery is **lease-based** (at-least-once): a ``get_batch`` does not
    destroy the dequeued envelopes -- they move to an in-flight ledger
    under a lease held by the receiving thread, and only an ``ack``
    removes them for good.  A lease that is never acked (consumer death,
    dropped response frame) expires after the transport's
    ``lease_timeout`` and its envelopes are requeued for redelivery, so
    no failure between dequeue and handoff can lose a task.  Consumers
    ack *after* the work is safely handed off (result published, batch
    relayed downstream); acks are piggybacked on the next frame so the
    hot path stays one round-trip per batch.  Calling ``get_batch``
    again on the same thread implicitly acks the previous still-held
    lease (the poll-is-commit backstop), so naive drain loops keep their
    pre-lease semantics.  Redelivery can race a slow-but-alive original
    consumer; publishers that must be exactly-once dedup via
    ``put(..., claim=task_id)``.
    """

    def put(self, env: Envelope, claim: Optional[str] = None) -> bool:
        """Enqueue an envelope.  When ``claim`` is given, the enqueue is
        fused with an atomic first-claim of that id: the envelope is only
        enqueued (and True returned) for the first claimant -- losing
        duplicates are swallowed in the same operation, leaving no window
        where an id is claimed but its envelope was never published."""
        raise NotImplementedError

    def get(self, timeout: Optional[float] = None,
            cancel: Optional[threading.Event] = None) -> Optional[Envelope]:
        batch = self.get_batch(1, timeout=timeout, cancel=cancel)
        return batch[0] if batch else None

    def get_batch(self, max_n: int, timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[Envelope]:
        raise NotImplementedError

    def ack(self, flush: bool = False) -> None:
        """Acknowledge this thread's held lease: the envelopes of the
        last ``get_batch`` are safely handed off and must never be
        redelivered.  Normally the ack piggybacks on the next outgoing
        frame (zero extra round-trips); ``flush=True`` forces it onto
        the wire immediately (e.g. right before a worker exits)."""
        raise NotImplementedError

    def held_lease(self) -> Optional[int]:
        """The lease id of this thread's last unacked ``get_batch``
        (None when nothing is held).  Consumers that execute for longer
        than ``lease_timeout`` read it here to hand to a heartbeat
        thread that keeps the lease alive via ``renew``."""
        raise NotImplementedError

    def detach_lease(self) -> Optional[int]:
        """Take over lease lifetime management: return the calling
        thread's held lease id and clear it, so the next ``get_batch``
        on this thread does NOT implicitly commit it (the poll-is-commit
        backstop only covers leases the thread still holds).  The caller
        becomes responsible for eventually ``ack_lease``-ing the id (or
        letting it expire and redeliver).  This is what lets a single
        intake thread keep draining while earlier batches are still
        executing -- e.g. an inference shard admitting new requests
        between decode steps of in-flight micro-batches."""
        raise NotImplementedError

    def ack_lease(self, lease_id: Optional[int],
                  flush: bool = False) -> None:
        """Acknowledge an explicit (detached) lease id: its envelopes
        are safely handed off and must never be redelivered.  Leases are
        addressed by (topic, kind, id), so any thread of the channel may
        ack them.  ``lease_id=None`` is a no-op; acking an id that
        already expired is a no-op (the redelivered re-execution will be
        deduped by the publisher's claim)."""
        raise NotImplementedError

    def renew(self, lease_id: Optional[int] = None) -> bool:
        """Extend a lease's expiry by another full ``lease_timeout``
        from now.  ``lease_id=None`` renews the calling thread's held
        lease.  Returns False when the lease no longer exists (already
        acked, or expired and redelivered -- too late: the renewal lost
        the race, and the claim fused into the result publish is what
        dedups the re-execution).  Long-running consumers renew at
        roughly half the lease timeout so tasks that legitimately
        outlive it never trigger a wasteful redelivery."""
        raise NotImplementedError

    def backup(self, lease_id: int, task_id: str,
               meta_update: dict) -> bool:
        """Clone one envelope of a live lease back onto the queue, with
        ``meta_update`` (placement hints like ``exclude_host``) merged
        into the copy's meta and ``backup=True`` set.  This is the
        straggler-mitigation primitive for the direct-subscription data
        plane: the supervisor never holds envelope bytes, but the lease
        ledger does -- so a backup is scheduled *where the original
        lives*, addressed by (lease_id, task_id).  The original lease is
        untouched (the slow consumer may still win); first completion
        arbitrates through the publish-fused claim as always.  Returns
        False when the lease is gone (acked or expired -- a backup is
        moot either way)."""
        raise NotImplementedError

    def wake(self) -> None:
        """Nudge every blocked consumer (shutdown/cancel propagation)."""
        raise NotImplementedError

    def cancel(self, task_id: str) -> bool:
        """Preempt a task by id (call on the topic's ``requests``
        channel).  Atomically: **claims** the id (so a racing completion
        dedups through the same fused put-claim path -- exactly one of
        cancel/complete wins), records it in the cancelled window,
        destroys every queued copy of the task (original, retry requeue,
        straggler backup clone -- unlinking any shm payload segments),
        strips it out of live leases (revoking in-flight delivery: the
        executing worker's eventual ack/expiry no longer requeues it),
        and wakes parked getters so freed capacity is re-steered
        immediately.  Returns True when this cancel won the claim; False
        when the id was already claimed (completion beat the cancel --
        the result is or will be delivered) or already cancelled.
        Signalling the *executing* worker is cooperative and rides on
        top: ``put_stream``/``is_cancelled`` answer "cancelled" and the
        worker aborts at its next observation or heartbeat."""
        raise NotImplementedError

    def put_stream(self, env: Envelope, task_id: str) -> bool:
        """Publish a mid-task observation onto this topic's ``stream``
        lane, fused with a cancellation probe: when ``task_id`` is
        already cancelled the observation is dropped and True is
        returned (the worker's cue to abort), else it is enqueued for
        the Thinker's ``process_intermediate`` drain and False is
        returned.  Observations ride under the task's lease -- they are
        advisory partials, so the stream lane itself needs no claims."""
        raise NotImplementedError

    def is_cancelled(self, task_id: str) -> bool:
        """Read-only probe of the cancelled window (idempotent; safe to
        retry).  Pool-worker heartbeats poll this between renews so a
        cancel reaches a worker that publishes no observations."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class Transport:
    """Factory of channels plus fabric-wide control operations."""

    name = "base"
    #: seconds before an unacked lease expires and its envelopes requeue.
    #: Must exceed the longest consumer hold (a pool worker holds its
    #: dispatch lease for the task's full execution); premature expiry is
    #: *safe* (claim dedups the raced completions) but wasteful.
    lease_timeout: float = 30.0

    def channel(self, topic: str, kind: str) -> Channel:
        raise NotImplementedError

    def wake_all(self) -> None:
        raise NotImplementedError

    def claim(self, task_id: str) -> bool:
        """Atomic first-completion claim (straggler-race dedup across
        processes).  Returns True for exactly one claimant per id.
        Prefer ``Channel.put(env, claim=id)`` which fuses the claim with
        the publish; this standalone op remains for callers that need
        the arbitration without an enqueue."""
        raise NotImplementedError

    def snapshot(self) -> bytes:
        """Serialize every queue's state -- queued envelopes, in-flight
        leases (as durations, so the bytes carry no wall-clock and a
        snapshot->restore->snapshot round-trip is byte-identical), wake
        epochs, and the claim/dedup window.  Implementations MUST
        capture all queues plus the claim window as one consistent cut
        (both backends hold the claim guard and every queue's Condition
        simultaneously): a one-queue-at-a-time capture could image a
        claim without its published result, or miss an envelope
        mid-relay between queues -- both are lost tasks after a resume,
        which checkpoint/resume's zero-loss guarantee forbids."""
        raise NotImplementedError

    def restore(self, data: bytes, expire_leases: bool = False) -> None:
        """Replace this transport's queue state with a ``snapshot``.
        By default restored in-flight leases re-arm for their full
        duration and requeue on expiry (state-faithful: a
        restore->snapshot round-trip is byte-identical).  Pass
        ``expire_leases=True`` when the previous incarnation is known
        dead (``ColmenaQueues.resume`` does): leased envelopes requeue
        immediately instead of waiting out leases nobody holds.
        Intended for a *fresh* fabric before consumers start."""
        raise NotImplementedError

    def close(self) -> None:
        """Tear down any processes/sockets owned by this transport."""
