"""In-process transport: per-channel Condition-notified deques.

This is the PR-1 ``_WakeQueue`` fabric, factored out of ``queues.py`` so
it sits behind the same ``Transport`` interface as the socket backend.
Consumers park on the condition until a ``put`` (or an external ``wake``,
e.g. shutdown) notifies them, and can drain a batch per wakeup -- there is
no timeout-polling anywhere on the dispatch or result-consumption path.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.transport.base import (BoundedIdSet, Channel, Envelope,
                                       Transport)
from repro.utils.timing import now


class LocalChannel(Channel):
    """FIFO of envelopes with Condition-notified blocking consumers."""

    def __init__(self):
        self._items: "deque[Envelope]" = deque()
        self._cond = threading.Condition()

    def put(self, env: Envelope) -> None:
        with self._cond:
            self._items.append(env)
            self._cond.notify()

    def get(self, timeout: Optional[float] = None,
            cancel: Optional[threading.Event] = None) -> Optional[Envelope]:
        deadline = None if timeout is None else now() + timeout
        with self._cond:
            while True:
                if self._items:
                    return self._items.popleft()
                if cancel is not None and cancel.is_set():
                    return None
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - now()
                    if remaining <= 0:
                        return None
                    self._cond.wait(remaining)

    def get_batch(self, max_n: int, timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[Envelope]:
        first = self.get(timeout=timeout, cancel=cancel)
        if first is None:
            return []
        out = [first]
        with self._cond:
            while self._items and len(out) < max_n:
                out.append(self._items.popleft())
        return out

    def wake(self) -> None:
        with self._cond:
            self._cond.notify_all()

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)


class LocalTransport(Transport):
    name = "local"

    def __init__(self, claim_window: int = 1 << 16):
        self._channels: Dict[Tuple[str, str], LocalChannel] = {}
        self._lock = threading.Lock()
        self._claimed = BoundedIdSet(claim_window)

    def channel(self, topic: str, kind: str) -> LocalChannel:
        with self._lock:
            ch = self._channels.get((topic, kind))
            if ch is None:
                ch = self._channels[(topic, kind)] = LocalChannel()
            return ch

    def wake_all(self) -> None:
        with self._lock:
            channels = list(self._channels.values())
        for ch in channels:
            ch.wake()

    def claim(self, task_id: str) -> bool:
        with self._lock:
            return self._claimed.claim(task_id)

    def close(self) -> None:
        self.wake_all()
