"""In-process transport: per-channel Condition-notified deques.

This is the PR-1 ``_WakeQueue`` fabric, factored out of ``queues.py`` so
it sits behind the same ``Transport`` interface as the socket backend.
Consumers park on the condition until a ``put`` (or an external ``wake``,
e.g. shutdown) notifies them, and can drain a batch per wakeup -- there is
no timeout-polling anywhere on the dispatch or result-consumption path.

Delivery is leased exactly like the broker's (see ``base.Channel``): a
``get_batch`` moves envelopes to an in-flight ledger under a per-thread
lease, ``ack`` removes them for good, and an unacked lease expires after
``lease_timeout`` and requeues -- parked getters bound their waits by the
earliest lease deadline and run the expiry themselves, so redelivery
needs no sweeper thread.  The local backend has no consumer *processes*
to die, but implementing the identical interface in-process means every
lease/ack/snapshot test parametrizes over both backends.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro import observability as obs
from repro.core.transport.base import (BoundedIdSet, Channel, Envelope,
                                       Transport, dump_snapshot,
                                       load_snapshot)
from repro.utils.timing import now


class LocalChannel(Channel):
    """FIFO of envelopes with Condition-notified blocking consumers and
    an in-flight lease ledger for at-least-once delivery."""

    def __init__(self, transport: "LocalTransport", topic: str = "",
                 kind: str = ""):
        self._t = transport
        self.topic = topic
        self.kind = kind
        self._items: "deque[Envelope]" = deque()
        self._cond = threading.Condition()
        self.epoch = 0                        # parity with the broker queue
        # lease_id -> (duration, deadline, [Envelope, ...]); all access
        # under self._cond
        self._leases: Dict[int, Tuple[float, float, List[Envelope]]] = {}
        self._next_lease = 0
        self._tls = threading.local()         # .held: this thread's lease

    # -- lease plumbing (call with self._cond held) -------------------------

    def _expire_locked(self) -> None:
        if not self._leases:
            return
        tnow = now()
        expired = [lid for lid, (_, deadline, _) in self._leases.items()
                   if deadline <= tnow]
        if not expired:
            return
        obs.counter("expired_leases").inc(len(expired))
        for lid in expired:
            _, _, envs = self._leases.pop(lid)
            obs.counter("redeliveries").inc(len(envs))
            for env in reversed(envs):
                meta = dict(env.meta)
                meta["redelivered"] = meta.get("redelivered", 0) + 1
                self._items.appendleft(Envelope(env.t_put, env.data, meta))
        self._cond.notify_all()

    def _next_lease_deadline_locked(self) -> Optional[float]:
        if not self._leases:
            return None
        return min(deadline for _, deadline, _ in self._leases.values())

    # -- Channel interface --------------------------------------------------

    def put(self, env: Envelope, claim: Optional[str] = None) -> bool:
        if claim is not None:
            # the claim guard is held ACROSS the enqueue (lock order:
            # transport lock -> cond, same as snapshot) so a snapshot
            # can never capture the claim without its result
            with self._t._lock:
                if not self._t._claimed.claim(claim):
                    obs.counter("claim_rejects").inc()
                    return False
                with self._cond:
                    self._items.append(env)
                    self._cond.notify()
            return True
        with self._cond:
            self._items.append(env)
            self._cond.notify()
        return True

    def get_batch(self, max_n: int, timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[Envelope]:
        self.ack()                            # poll-is-commit backstop
        deadline = None if timeout is None else now() + timeout
        with self._cond:
            while True:
                self._expire_locked()
                if self._items:
                    out = []
                    while self._items and len(out) < max_n:
                        env = self._items.popleft()
                        tid = env.meta.get("task_id")
                        # a cancelled id's envelope is dead work: destroy
                        # it here (backstop for a retry-requeue or
                        # redelivery racing the cancel's strip)
                        if tid is not None and tid in self._t._cancelled:
                            continue
                        out.append(env)
                    if not out:
                        continue              # drained only cancelled work
                    lid = self._next_lease
                    self._next_lease += 1
                    dur = self._t.lease_timeout
                    # `out` is returned to exactly one caller and never
                    # mutated: the ledger can share it (no copy)
                    self._leases[lid] = (dur, now() + dur, out)
                    if len(self._leases) == 1:
                        # getters parked before any lease existed wait
                        # unbounded: wake them to re-arm their park
                        # bounded by this lease's expiry (see broker.get)
                        self._cond.notify_all()
                    self._tls.held = lid
                    t_grant = now()
                    for env in out:
                        if env.meta.get("trace") and env.meta.get("task_id"):
                            obs.span(env.meta["task_id"], "queue_wait",
                                     env.t_put, t_grant,
                                     attempt=int(env.meta.get(
                                         "redelivered", 0) or 0))
                    return out
                if cancel is not None and cancel.is_set():
                    return []
                remaining = None
                if deadline is not None:
                    remaining = deadline - now()
                    if remaining <= 0:
                        return []
                lease_dl = self._next_lease_deadline_locked()
                if lease_dl is not None:
                    until_lease = max(lease_dl - now(), 0.0)
                    remaining = (until_lease if remaining is None
                                 else min(remaining, until_lease))
                if remaining is None:
                    self._cond.wait()
                else:
                    self._cond.wait(remaining)

    def ack(self, flush: bool = False) -> None:
        held = getattr(self._tls, "held", None)
        if held is None:
            return
        self._tls.held = None
        with self._cond:
            self._leases.pop(held, None)      # already expired: no-op

    def held_lease(self) -> Optional[int]:
        return getattr(self._tls, "held", None)

    def detach_lease(self) -> Optional[int]:
        held = getattr(self._tls, "held", None)
        self._tls.held = None
        return held

    def ack_lease(self, lease_id: Optional[int],
                  flush: bool = False) -> None:
        if lease_id is None:
            return
        with self._cond:
            self._leases.pop(lease_id, None)  # already expired: no-op

    def backup(self, lease_id: int, task_id: str,
               meta_update: dict) -> bool:
        with self._cond:
            lease = self._leases.get(lease_id)
            if lease is None:
                return False                  # acked or already expired
            for env in lease[2]:
                if env.meta.get("task_id") == task_id:
                    meta = dict(env.meta)
                    meta.update(meta_update)
                    meta["backup"] = True
                    self._items.append(Envelope(env.t_put, env.data, meta))
                    self._cond.notify()
                    return True
        return False

    def renew(self, lease_id: Optional[int] = None) -> bool:
        lid = lease_id if lease_id is not None else self.held_lease()
        if lid is None:
            return False
        with self._cond:
            lease = self._leases.get(lid)
            if lease is None:
                return False                  # acked or already expired
            dur, _, envs = lease
            self._leases[lid] = (dur, now() + dur, envs)
            return True

    def wake(self) -> None:
        with self._cond:
            self.epoch += 1
            self._cond.notify_all()

    def cancel(self, task_id: str) -> bool:
        # claim + cancelled-window write + queue/lease strip as one
        # atomic step under the transport lock, channel Conditions nested
        # inside in sorted (topic, kind) order -- the same lock order as
        # put-with-claim and snapshot, so a snapshot can never image the
        # claim without the strip (and the witness learns no new edges)
        with self._t._lock:
            if not self._t._claimed.claim(task_id):
                return False                  # completion (or an earlier
                                              # cancel) already won
            self._t._cancelled.add(task_id)
            chans = [ch for (t, k), ch in sorted(self._t._channels.items())
                     if t == self.topic and k in ("requests", "stream")]
            for ch in chans:
                with ch._cond:
                    ch._items = deque(
                        e for e in ch._items
                        if e.meta.get("task_id") != task_id)
                    for lid in list(ch._leases):
                        dur, dl, envs = ch._leases[lid]
                        live = [e for e in envs
                                if e.meta.get("task_id") != task_id]
                        if len(live) == len(envs):
                            continue
                        if live:
                            ch._leases[lid] = (dur, dl, live)
                        else:
                            # nothing left under the lease (e.g. a
                            # straggler backup clone's whole delivery):
                            # drop it -- expiry would requeue nothing
                            del ch._leases[lid]
                    # wake parked getters: capacity freed by the strip is
                    # re-steerable immediately, and an idle getter parked
                    # in an unbounded wait re-checks its cancel Event
                    # (the PR-7 stop-envelope hazard)
                    ch.epoch += 1
                    ch._cond.notify_all()
        obs.counter("tasks_cancelled").inc()
        return True

    def put_stream(self, env: Envelope, task_id: str) -> bool:
        # membership read without the transport lock: GIL-atomic, and a
        # cancel racing this publish is benign -- the worker aborts at
        # its next probe and the get path destroys the stale observation
        if task_id in self._t._cancelled:
            obs.counter("observations_dropped").inc()
            return True
        with self._cond:
            self._items.append(env)
            self._cond.notify()
        return False

    def is_cancelled(self, task_id: str) -> bool:
        return task_id in self._t._cancelled  # GIL-atomic read

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    def inflight_count(self) -> int:
        with self._cond:
            return sum(len(envs) for _, _, envs in self._leases.values())


class LocalTransport(Transport):
    name = "local"

    def __init__(self, claim_window: int = 1 << 16,
                 lease_timeout: float = 30.0):
        self._channels: Dict[Tuple[str, str], LocalChannel] = {}
        self._lock = threading.Lock()
        self._claimed = BoundedIdSet(claim_window)
        # preempted ids: written under self._lock (cancel), read lock-free
        self._cancelled = BoundedIdSet(claim_window)
        self.lease_timeout = lease_timeout

    def channel(self, topic: str, kind: str) -> LocalChannel:
        with self._lock:
            ch = self._channels.get((topic, kind))
            if ch is None:
                ch = self._channels[(topic, kind)] = LocalChannel(
                    self, topic, kind)
            return ch

    def wake_all(self) -> None:
        with self._lock:
            channels = list(self._channels.values())
        for ch in channels:
            ch.wake()

    def claim(self, task_id: str) -> bool:
        with self._lock:
            return self._claimed.claim(task_id)

    def clock_sync(self) -> float:
        """Interface parity with ``ProcTransport.clock_sync``: everything
        shares this process's clock, so the reference time IS ``now()``
        (calibration against it converges on a ~zero offset)."""
        return now()

    # -- snapshot/restore ---------------------------------------------------

    def snapshot(self) -> bytes:
        """Consistent global cut, mirroring the broker: the transport
        lock (which guards claims) plus every channel Condition are held
        simultaneously, so no claim-fused put and no envelope mid-relay
        between channels can straddle the image."""
        from contextlib import ExitStack
        with ExitStack() as stack:
            stack.enter_context(self._lock)
            channels = sorted(self._channels.items())
            for _, ch in channels:
                stack.enter_context(ch._cond)
            queues = []
            for (topic, kind), ch in channels:
                items = [(e.t_put, e.meta, e.data) for e in ch._items]
                leases = sorted(
                    (lid, dur, [(e.t_put, e.meta, e.data) for e in envs])
                    for lid, (dur, _, envs) in ch._leases.items())
                queues.append((topic, kind, ch.epoch, items, leases))
            order = list(self._claimed._order)
            maxlen = self._claimed.maxlen
            c_order = list(self._cancelled._order)
            c_maxlen = self._cancelled.maxlen
        return dump_snapshot(queues, maxlen, order, c_maxlen, c_order)

    def restore(self, data: bytes, expire_leases: bool = False) -> None:
        state = load_snapshot(data)
        tnow = now()
        for topic, kind, epoch, items, leases in state["queues"]:
            ch = self.channel(topic, kind)
            with ch._cond:
                ch._items = deque(Envelope(t, d, m) for t, m, d in items)
                ch.epoch = epoch
                # deadline = tnow when expiring: the holders died with the
                # previous incarnation, so the next expiry check requeues
                ch._leases = {
                    lid: (dur, tnow if expire_leases else tnow + dur,
                          [Envelope(t, d, m) for t, m, d in envs])
                    for lid, dur, envs in leases}
                if ch._leases:
                    ch._next_lease = max(ch._leases) + 1
                if expire_leases:
                    ch._expire_locked()
                ch._cond.notify_all()
        with self._lock:
            claimed = BoundedIdSet(state["claims"]["maxlen"])
            for cid in state["claims"]["order"]:
                claimed.add(cid)
            self._claimed = claimed
            # a cancelled id must stay cancelled across resume: restored
            # stale envelopes of preempted tasks are destroyed on get
            canc = state.get("cancelled")
            if canc:
                cancelled = BoundedIdSet(canc["maxlen"]
                                         or self._cancelled.maxlen)
                for cid in canc["order"]:
                    cancelled.add(cid)
                self._cancelled = cancelled

    def close(self) -> None:
        self.wake_all()
