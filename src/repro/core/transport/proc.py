"""Client side of the socket fabric: ``ProcTransport`` + ``ProcChannel``.

``ProcTransport()`` binds a Unix-domain socket (TCP fallback), forks the
broker process on it, and hands out ``ProcChannel`` objects whose
``put``/``get_batch`` translate one-to-one into broker frames.  Consumers
block in ``recv`` while the broker parks their handler thread on the queue
Condition -- there is no polling on either side of the wire.  The
transport object is safe to capture in forked workers: its ``FrameClient``
reopens connections per (pid, thread).
"""
from __future__ import annotations

import atexit
import multiprocessing
import os
import tempfile
import threading
from typing import List, Optional

from repro.core.transport import frames
from repro.core.transport.base import Channel, Envelope, Transport
from repro.core.transport.broker import broker_main
from repro.utils.timing import now

_mp = multiprocessing.get_context("fork")


class ProcChannel(Channel):
    def __init__(self, transport: "ProcTransport", topic: str, kind: str):
        self._t = transport
        self.topic = topic
        self.kind = kind
        # last wake epoch observed from the broker, tracked PER THREAD
        # (like FrameClient's sockets): the broker only parks a get whose
        # epoch is current, so a wake_all landing between a thread's
        # cancel check and its request is detected, never lost -- and one
        # consumer thread absorbing a wake cannot advance a sibling
        # consumer's epoch past the wake it still needs to observe
        self._tls = threading.local()

    def put(self, env: Envelope) -> None:
        self._t.client.request(
            {"op": "put", "topic": self.topic, "kind": self.kind,
             "t_put": env.t_put, "meta": env.meta}, env.data)

    def get_batch(self, max_n: int, timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[Envelope]:
        deadline = None if timeout is None else now() + timeout
        while True:
            if cancel is not None and cancel.is_set():
                return []
            remaining = None
            if deadline is not None:
                remaining = deadline - now()
                if remaining <= 0:
                    return []
            epoch = getattr(self._tls, "epoch", None)
            header, blob = self._t.client.request(
                {"op": "get", "topic": self.topic, "kind": self.kind,
                 "max_n": max_n, "timeout": remaining,
                 "epoch": epoch}, retry=True)
            self._tls.epoch = header["epoch"]
            if header["envs"]:
                out, off = [], 0
                for t_put, meta, n in header["envs"]:
                    out.append(Envelope(t_put, blob[off:off + n], meta))
                    off += n
                return out
            if not header["woken"]:
                return []                   # server-side timeout lapsed
            # woken (wake_all) or first-request epoch sync: re-check
            # cancel/deadline, then re-park with a current epoch

    def wake(self) -> None:
        self._t.wake_all()

    def __len__(self) -> int:
        header, _ = self._t.client.request(
            {"op": "len", "topic": self.topic, "kind": self.kind},
            retry=True)
        return header["n"]


class ProcTransport(Transport):
    name = "proc"

    def __init__(self, address: Optional[tuple] = None):
        """address: connect to an existing broker (another process's
        fabric); None forks a fresh broker owned by this transport."""
        self._proc = None
        self._dir = None
        self._owner_pid = os.getpid()
        if address is None:
            self._dir = tempfile.mkdtemp(prefix="colmena-broker-")
            sock, address = frames.make_server_socket(
                os.path.join(self._dir, "broker.sock"))
            self._proc = _mp.Process(target=broker_main, args=(sock,),
                                     daemon=True, name="colmena-broker")
            self._proc.start()
            sock.close()                    # the broker child owns it now
            atexit.register(self.close)
        self.address = address
        self.client = frames.FrameClient(address)

    def channel(self, topic: str, kind: str) -> ProcChannel:
        return ProcChannel(self, topic, kind)

    def wake_all(self) -> None:
        try:
            self.client.request({"op": "wake"}, retry=True)
        except (ConnectionError, OSError):
            pass                    # broker already torn down: nothing parked

    def claim(self, task_id: str) -> bool:
        header, _ = self.client.request({"op": "claim", "id": task_id})
        return header["claimed"]

    def close(self) -> None:
        # only the process that forked the broker may tear it down
        if self._proc is None or os.getpid() != self._owner_pid:
            return
        proc, self._proc = self._proc, None
        try:
            self.client.request({"op": "shutdown"})
        except (ConnectionError, OSError):
            pass
        self.client.close()
        proc.join(timeout=2)
        if proc.is_alive():
            proc.terminate()
        if self._dir is not None:
            import shutil
            shutil.rmtree(self._dir, ignore_errors=True)
