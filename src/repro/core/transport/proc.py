"""Client side of the socket fabric: ``ProcTransport`` + ``ProcChannel``.

``ProcTransport()`` binds a Unix-domain socket (TCP fallback), forks the
broker process on it, and hands out ``ProcChannel`` objects whose
``put``/``get_batch`` translate one-to-one into broker frames.  Consumers
block in ``recv`` while the broker parks their handler thread on the queue
Condition -- there is no polling on either side of the wire.  The
transport object is safe to capture in forked workers: its ``FrameClient``
reopens connections per (pid, thread).

Two data-plane optimizations live here, both discovered (not configured)
through the broker's ``endpoints`` op:

- **Direct routing.**  In a federation, each topic is homed at exactly
  one member broker.  Rather than sending every frame to the local
  broker and letting it relay, a channel resolves its topic's home from
  the advertised peer map and dials that broker directly -- zero relay
  hops on the data plane.  The relay path remains as the fallback (a
  frame that does land at a non-home member is still forwarded), and
  control traffic (``wake``, ``claim``, snapshots, ack flushes) keeps
  going through the connected broker, which owns the broadcast /
  coordinator semantics.
- **Shared-memory payload lane.**  When the destination broker is
  co-located (same machine, advertises a shm scope), a payload at or
  above ``shm_threshold`` is written once into a shared-memory segment
  (``transport.shm``) and only its descriptor rides the frame header;
  co-located consumers advertise ``shm_ok`` on their gets and map the
  segment themselves.  Segment lifetime is tied to the envelope's
  lease/ack lifecycle at the broker (see ``shm.py``'s ownership
  protocol); the wire format is unchanged for remote or under-threshold
  frames.

Delivery is leased (see ``base.Channel``): every non-empty ``get``
response carries a lease id, and the envelopes are only destroyed when
the consumer acks it.  Acks accumulate in a transport-level pending set
and piggyback on the *next* outgoing frame -- any frame, to any broker
of the fabric; a member receiving acks for topics homed elsewhere
forwards them (``federation._route_acks``).  If a frame carrying acks
dies with its connection, the acks are restored to the pending set: the
worst case is a redundant redelivery that the publisher-side ``claim``
dedups, never a lost task.
"""
from __future__ import annotations

import atexit
import multiprocessing
import os
import socket as socketlib
import tempfile
import threading
from typing import List, Optional, Tuple

from repro import observability as obs
from repro.core.transport import frames, shm
from repro.core.transport.base import Channel, Envelope, Transport
from repro.core.transport.broker import broker_main
from repro.utils.timing import now

_mp = multiprocessing.get_context("fork")

_LOCAL_HOSTS = ("localhost", "127.0.0.1", "::1")


class ProcChannel(Channel):
    def __init__(self, transport: "ProcTransport", topic: str, kind: str):
        self._t = transport
        self.topic = topic
        self.kind = kind
        # the topic's home-broker client and whether that broker is
        # co-located (shm lane eligible); resolved lazily on first use --
        # both threads of a benign race compute the same cached client
        self._client: Optional[frames.FrameClient] = None
        self._local = False
        # wake epoch and held lease observed from the broker, tracked PER
        # THREAD (like FrameClient's sockets): the broker only parks a get
        # whose epoch is current, so a wake_all landing between a thread's
        # cancel check and its request is detected, never lost -- and one
        # consumer thread absorbing a wake (or acking its lease) cannot
        # clobber a sibling consumer's epoch or lease
        self._tls = threading.local()

    def _dc(self) -> frames.FrameClient:
        """This topic's home-broker client (direct data plane)."""
        c = self._client
        if c is None:
            c, local = self._t.client_for(self.topic)
            self._local = local
            self._client = c
        return self._client

    def put(self, env: Envelope, claim: Optional[str] = None) -> bool:
        client = self._dc()
        header = {"op": "put", "topic": self.topic, "kind": self.kind,
                  "t_put": env.t_put, "meta": env.meta}
        if claim is not None:
            header["claim"] = claim
        payload = env.data
        traced = env.meta.get("trace") and env.meta.get("task_id")
        t0 = now() if traced else 0.0
        desc = self._t.export_payload(payload) if self._local else None
        if desc is not None:
            if traced:
                obs.span(env.meta["task_id"], "shm_write", t0, now(),
                         size=len(payload))
            header["shm"] = desc
            payload = b""
        # NOTE on a failed request after export: the segment is NOT
        # unlinked here.  A connection error is ambiguous -- the broker
        # may have received the frame and now owns the segment; unlinking
        # would destroy a delivered envelope's payload.  The leak is
        # bounded: teardown sweeps the fabric's scope (shm.sweep_scope).
        resp, _ = self._t.request(header, payload, client=client)
        return resp.get("claimed", True)

    def get_batch(self, max_n: int, timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[Envelope]:
        self.ack()                          # poll-is-commit backstop
        client = self._dc()
        deadline = None if timeout is None else now() + timeout
        while True:
            if cancel is not None and cancel.is_set():
                return []
            remaining = None
            if deadline is not None:
                remaining = deadline - now()
                if remaining <= 0:
                    return []
            epoch = getattr(self._tls, "epoch", None)
            # NOTE no retry= here: a broker-side get is a *leased* dequeue,
            # so a response frame lost with its connection only strands a
            # lease that expires and redelivers -- but an automatic
            # reconnect-resend would still fetch *different* envelopes
            # under a fresh lease while this caller believes it asked
            # once.  Surfacing the error keeps the failure visible; the
            # lease ledger (not a resend) is what makes it recoverable.
            header, blob = self._t.request(
                {"op": "get", "topic": self.topic, "kind": self.kind,
                 "max_n": max_n, "timeout": remaining,
                 "lease_timeout": self._t.lease_timeout,
                 "epoch": epoch, "shm_ok": self._local},
                client=client)
            self._tls.epoch = header["epoch"]
            if header["envs"]:
                self._tls.held = header["lease"]
                out, off = [], 0
                for t_put, meta, n in header["envs"]:
                    if "_shm" in meta:
                        # out-of-band payload: map the co-located segment
                        # (read-only -- consumers never unlink, see shm.py)
                        meta = dict(meta)
                        desc = meta.pop("_shm")
                        t0 = (now() if meta.get("trace")
                              and meta.get("task_id") else 0.0)
                        try:
                            data = shm.read_segment(desc)
                        except OSError:
                            # our lease expired mid-flight and the
                            # redelivered copy's consumer already acked
                            # (destroying the segment): this copy lost the
                            # race anyway -- drop it, the claim dedups
                            continue
                        if t0:
                            obs.span(meta["task_id"], "shm_read", t0, now(),
                                     size=len(data))
                        out.append(Envelope(t_put, data, meta))
                        continue
                    out.append(Envelope(t_put, blob[off:off + n], meta))
                    off += n
                if out:
                    return out
                continue                    # every item raced: re-get
            if not header["woken"]:
                return []                   # server-side timeout lapsed
            # woken (wake_all) or first-request epoch sync: re-check
            # cancel/deadline, then re-park with a current epoch

    def ack(self, flush: bool = False) -> None:
        held = getattr(self._tls, "held", None)
        if held is not None:
            self._tls.held = None
            self._t.queue_ack((self.topic, self.kind, held))
        if flush:
            self._t.flush_acks()

    def held_lease(self) -> Optional[int]:
        return getattr(self._tls, "held", None)

    def detach_lease(self) -> Optional[int]:
        held = getattr(self._tls, "held", None)
        self._tls.held = None
        return held

    def ack_lease(self, lease_id: Optional[int],
                  flush: bool = False) -> None:
        if lease_id is None:
            return
        self._t.queue_ack((self.topic, self.kind, lease_id))
        if flush:
            self._t.flush_acks()

    def renew(self, lease_id: Optional[int] = None) -> bool:
        """Heartbeat a lease (the holder's, or an explicit id handed to
        a heartbeat thread -- leases are addressed by (topic, kind, id),
        so any thread's connection can renew them).  Deliberately not
        retried: a renew that died on the wire just means the next
        heartbeat tick renews a little later."""
        lid = lease_id if lease_id is not None else self.held_lease()
        if lid is None:
            return False
        header, _ = self._t.request(
            {"op": "renew", "topic": self.topic, "kind": self.kind,
             "lease": lid}, client=self._dc())
        return header["ok"]

    def backup(self, lease_id: int, task_id: str,
               meta_update: dict) -> bool:
        """Ask the broker to clone a leased envelope back onto the queue
        (straggler backup; see ``Broker.backup``).  Deliberately not
        retried: a resend of a backup that was applied before its
        connection died would enqueue a second clone -- harmless (claim
        dedup) but wasteful, and the straggler timer re-fires anyway."""
        header, _ = self._t.request(
            {"op": "backup", "topic": self.topic, "kind": self.kind,
             "lease": lease_id, "id": task_id, "meta": meta_update},
            client=self._dc())
        return header["ok"]

    def wake(self) -> None:
        self._t.wake_all()

    def cancel(self, task_id: str) -> bool:
        """Broker-side preemption (see ``Broker.cancel``).  Deliberately
        not retried: a resend of a cancel that was applied before its
        connection died would answer won=False to the rightful first
        canceller, who would then wrongly expect a result envelope."""
        header, _ = self._t.request(
            {"op": "cancel", "topic": self.topic, "id": task_id},
            client=self._dc())
        return header["won"]

    def put_stream(self, env: Envelope, task_id: str) -> bool:
        """Observation publish fused with the cancel probe (True = task
        cancelled, observation dropped).  Observations are small and
        advisory, so there is no shm lane here; deliberately not retried
        (a resend could double-publish an observation -- a missed one is
        harmless, the next publish carries fresher state anyway)."""
        header, _ = self._t.request(
            {"op": "put_stream", "topic": self.topic, "t_put": env.t_put,
             "meta": env.meta}, env.data, client=self._dc())
        return header.get("cancelled", False)

    def is_cancelled(self, task_id: str) -> bool:
        """Read-only probe of the cancelled window (idempotent, so the
        heartbeat's probe survives a reconnect)."""
        header, _ = self._t.request(
            {"op": "cancelled", "topic": self.topic, "id": task_id},
            retry=True, client=self._dc())
        return header["cancelled"]

    def __len__(self) -> int:
        header, _ = self._t.request(
            {"op": "len", "topic": self.topic, "kind": self.kind},
            retry=True, client=self._dc())
        return header["n"]


class ProcTransport(Transport):
    name = "proc"

    def __init__(self, address: Optional[tuple] = None,
                 lease_timeout: float = 30.0,
                 snapshot_every: float = 0.0,
                 snapshot_path: Optional[str] = None,
                 shm_threshold: Optional[int] = None):
        """address: connect to an existing broker (another process's
        fabric, or a cluster launcher's per-host federated broker); None
        forks a fresh broker owned by this transport.
        lease_timeout: seconds before an unacked get lease expires and
        its envelopes are redelivered; must exceed the longest consumer
        hold (a pool worker holds its lease for the task's execution)
        unless that consumer heartbeats via ``Channel.renew``.
        snapshot_every/snapshot_path: broker-side periodic auto-snapshot
        (atomic tmp+rename) -- crash protection with no application
        checkpoint call; only valid when this transport forks the
        broker (a remote broker configures its own).
        shm_threshold: payload size at which co-located frames switch to
        the shared-memory lane (default ``shm.SHM_THRESHOLD``)."""
        self._proc = None
        self._dir = None
        self._owner_pid = os.getpid()
        self.lease_timeout = lease_timeout
        self.shm_threshold = (shm.SHM_THRESHOLD if shm_threshold is None
                              else shm_threshold)
        self._pending_acks: list = []
        self._ack_lock = threading.Lock()
        # endpoints discovery + direct-client cache (lazy, lock-guarded)
        self._endpoints: Optional[dict] = None
        self._ep_lock = threading.Lock()
        self._direct_clients: dict = {}
        self._dc_lock = threading.Lock()
        self._shm_scope: Optional[str] = None   # active producer scope
        self._owned_scope: Optional[str] = None  # swept at close()
        if address is None:
            self._dir = tempfile.mkdtemp(prefix="colmena-broker-")
            sock, address = frames.make_server_socket(
                os.path.join(self._dir, "broker.sock"))
            if shm.shm_dir() is not None:
                self._owned_scope = shm.new_scope()
            self._proc = _mp.Process(
                target=broker_main,
                args=(sock, snapshot_every, snapshot_path,
                      self._owned_scope),
                daemon=True, name="colmena-broker")
            self._proc.start()
            sock.close()                    # the broker child owns it now
            atexit.register(self.close)
        elif snapshot_every:
            raise ValueError(
                "snapshot_every configures the broker this transport forks;"
                " a remote broker's auto-snapshot is configured where it is"
                " launched (ClusterSpec.snapshot_every)")
        self.address = address
        self.client = frames.FrameClient(address)

    # -- fork safety ----------------------------------------------------------

    def _after_fork(self) -> None:
        """A forked child inherits this transport's locks in whatever
        state the parent's threads held them at fork time -- a parent
        thread inside ``endpoints()`` leaves ``_ep_lock`` locked in the
        child *forever* (the owner lives in another process).  First use
        under a new pid therefore resets every transport-level mutable:
        fresh locks, empty direct-client cache (``FrameClient`` re-dials
        per pid anyway), no inherited pending acks (those are the
        parent's to flush), and cleared discovery/ownership state so the
        child re-discovers and can never tear down the parent's broker
        or sweep its shm scope.  Called from every entry point that
        touches a lock, ahead of acquiring it."""
        if os.getpid() == self._owner_pid:
            return
        self._owner_pid = os.getpid()
        self._ack_lock = threading.Lock()
        self._pending_acks = []
        self._ep_lock = threading.Lock()
        self._endpoints = None
        self._dc_lock = threading.Lock()
        self._direct_clients = {}
        self._shm_scope = None
        self._proc = None
        self._dir = None
        self._owned_scope = None

    # -- data-plane discovery -------------------------------------------------

    def endpoints(self) -> dict:
        """The connected broker's advertised topology: its federation
        host name (None for a plain broker), peer address map, topic
        partition, machine, and shm scope.  Discovered once, lazily,
        under a lock (double-checked: the fast path is one dict read);
        a broker predating the op degrades to the relay path."""
        self._after_fork()
        ep = self._endpoints
        if ep is not None:
            return ep
        with self._ep_lock:
            if self._endpoints is None:
                try:
                    header, _ = self.request({"op": "endpoints"},
                                             retry=True)
                except (ConnectionError, OSError, RuntimeError):
                    # unreachable or pre-endpoints broker: no direct
                    # routing, no shm lane -- every frame relays as before
                    header = {"host": None, "peers": {}, "partition": {},
                              "machine": None, "scope": None}
                if (header.get("scope")
                        and header.get("machine") == socketlib.gethostname()
                        and shm.shm_dir() is not None):
                    self._shm_scope = header["scope"]
                self._endpoints = header
        return self._endpoints

    @staticmethod
    def _addr_is_local(address) -> bool:
        """Whether a broker address is on this machine: a Unix-domain
        socket (a bare path, or ``("unix", path)`` as
        ``make_server_socket`` returns) always is; TCP only via loopback
        or our own hostname (the launcher's ssh path rewrites remote
        members to real hosts)."""
        if isinstance(address, (str, bytes)):
            return True
        host = address[0]
        return (host == "unix" or host in _LOCAL_HOSTS
                or host == socketlib.gethostname())

    def client_for(self, topic: str) -> Tuple[frames.FrameClient, bool]:
        """(client, co_located) for ``topic``'s home broker.  For a plain
        broker (or before/without discovery) that is the connected
        client; in a federation the topic's home is resolved from the
        advertised partition and dialed directly -- the same
        ``resolve_home`` every member routes by, so a direct frame is
        always local at its target."""
        ep = self.endpoints()
        host = ep.get("host")
        shm_on = self._shm_scope is not None
        if not host:
            return self.client, shm_on and self._addr_is_local(self.address)
        # deferred import: cluster.spec pulls in the cluster package,
        # which imports this module at load time
        from repro.core.cluster.spec import resolve_home
        home = resolve_home(topic, ep["partition"], sorted(ep["peers"]))
        if home == host:
            return self.client, shm_on and self._addr_is_local(self.address)
        addr = ep["peers"][home]
        with self._dc_lock:
            c = self._direct_clients.get(home)
            if c is None:
                c = self._direct_clients[home] = frames.FrameClient(addr)
        return c, shm_on and self._addr_is_local(addr)

    def export_payload(self, data: bytes) -> Optional[dict]:
        """Move ``data`` into a shared-memory segment if the lane is on
        and the payload is big enough; returns the descriptor to ride
        the frame header, or None to send inline.  Any shm failure
        (namespace full, swept scope) silently falls back to inline --
        the lane is an optimization, never a correctness dependency."""
        scope = self._shm_scope
        if scope is None or len(data) < self.shm_threshold:
            return None
        try:
            return shm.create_segment(scope, data)
        except OSError:
            return None

    # -- ack piggybacking ---------------------------------------------------

    def queue_ack(self, ack: tuple) -> None:
        self._after_fork()
        with self._ack_lock:
            self._pending_acks.append(ack)

    def flush_acks(self) -> None:
        """Force pending acks onto the wire now (normally they ride the
        next frame; use before exiting a consumer)."""
        self._after_fork()
        with self._ack_lock:
            if not self._pending_acks:
                return
        self.request({"op": "ack"})

    def request(self, header: dict, payload: bytes = b"",
                retry: bool = False, client=None):
        """All broker traffic funnels through here so any frame can carry
        the pending acks -- to any broker of the fabric: a federation
        member routes acks for topics homed elsewhere (so an ack queued
        against one home broker safely rides a frame to another).  On a
        failed send the acks are restored: they ride the next successful
        frame, and until then the leases just stay in-flight (expiry +
        claim dedup make that safe)."""
        self._after_fork()
        if client is None:
            client = self.client
        acks = None
        with self._ack_lock:
            if self._pending_acks:
                acks = self._pending_acks
                self._pending_acks = []
        if acks:
            header = dict(header)
            header["acks"] = acks
        try:
            return client.request(header, payload, retry=retry)
        except (ConnectionError, OSError):
            if acks:
                with self._ack_lock:
                    self._pending_acks = acks + self._pending_acks
            raise

    # -- Transport interface ------------------------------------------------

    def channel(self, topic: str, kind: str) -> ProcChannel:
        return ProcChannel(self, topic, kind)

    def wake_all(self) -> None:
        try:
            self.request({"op": "wake"}, retry=True)
        except (ConnectionError, OSError):
            pass                    # broker already torn down: nothing parked

    def clock_sync(self) -> float:
        """One roundtrip of the idempotent ``clock_sync`` op against the
        connected broker: returns the broker's ``now()``.  Feed it to
        ``observability.calibrate`` to estimate this process's clock
        offset onto that broker's timeline."""
        header, _ = self.request({"op": "clock_sync"}, retry=True)
        return float(header["t"])

    def claim(self, task_id: str) -> bool:
        # deliberately NOT retried: a resend of a claim that was applied
        # before the connection died would answer False to the rightful
        # first claimant
        header, _ = self.request({"op": "claim", "id": task_id})
        return header["claimed"]

    def snapshot(self) -> bytes:
        _, payload = self.request({"op": "snapshot"}, retry=True)
        return payload

    def restore(self, data: bytes, expire_leases: bool = False) -> None:
        self.request({"op": "restore", "expire_leases": expire_leases},
                     data, retry=True)

    def close(self) -> None:
        # only the process that forked the broker may tear it down
        if self._proc is None or os.getpid() != self._owner_pid:
            return
        proc, self._proc = self._proc, None
        try:
            self.client.request({"op": "shutdown"})
        except (ConnectionError, OSError):
            pass
        self.client.close()
        for c in self._direct_clients.values():
            c.close()
        proc.join(timeout=2)
        if proc.is_alive():
            proc.terminate()
        if self._owned_scope is not None:
            # the broker released live segments on graceful shutdown;
            # this sweep reclaims leaks no registry could see (producer
            # died pre-handoff, broker SIGKILLed)
            shm.sweep_scope(self._owned_scope)
        if self._dir is not None:
            import shutil
            shutil.rmtree(self._dir, ignore_errors=True)
