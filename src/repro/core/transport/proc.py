"""Client side of the socket fabric: ``ProcTransport`` + ``ProcChannel``.

``ProcTransport()`` binds a Unix-domain socket (TCP fallback), forks the
broker process on it, and hands out ``ProcChannel`` objects whose
``put``/``get_batch`` translate one-to-one into broker frames.  Consumers
block in ``recv`` while the broker parks their handler thread on the queue
Condition -- there is no polling on either side of the wire.  The
transport object is safe to capture in forked workers: its ``FrameClient``
reopens connections per (pid, thread).

Delivery is leased (see ``base.Channel``): every non-empty ``get``
response carries a lease id, and the envelopes are only destroyed when
the consumer acks it.  Acks accumulate in a transport-level pending set
and piggyback on the *next* outgoing frame -- any frame, to any channel
of the same broker -- so committing a batch costs zero extra round
trips.  If a frame carrying acks dies with its connection, the acks are
restored to the pending set: the worst case is a redundant redelivery
that the publisher-side ``claim`` dedups, never a lost task.
"""
from __future__ import annotations

import atexit
import multiprocessing
import os
import tempfile
import threading
from typing import List, Optional

from repro.core.transport import frames
from repro.core.transport.base import Channel, Envelope, Transport
from repro.core.transport.broker import broker_main
from repro.utils.timing import now

_mp = multiprocessing.get_context("fork")


class ProcChannel(Channel):
    def __init__(self, transport: "ProcTransport", topic: str, kind: str):
        self._t = transport
        self.topic = topic
        self.kind = kind
        # wake epoch and held lease observed from the broker, tracked PER
        # THREAD (like FrameClient's sockets): the broker only parks a get
        # whose epoch is current, so a wake_all landing between a thread's
        # cancel check and its request is detected, never lost -- and one
        # consumer thread absorbing a wake (or acking its lease) cannot
        # clobber a sibling consumer's epoch or lease
        self._tls = threading.local()

    def put(self, env: Envelope, claim: Optional[str] = None) -> bool:
        header = {"op": "put", "topic": self.topic, "kind": self.kind,
                  "t_put": env.t_put, "meta": env.meta}
        if claim is not None:
            header["claim"] = claim
        resp, _ = self._t.request(header, env.data)
        return resp.get("claimed", True)

    def get_batch(self, max_n: int, timeout: Optional[float] = None,
                  cancel: Optional[threading.Event] = None
                  ) -> List[Envelope]:
        self.ack()                          # poll-is-commit backstop
        deadline = None if timeout is None else now() + timeout
        while True:
            if cancel is not None and cancel.is_set():
                return []
            remaining = None
            if deadline is not None:
                remaining = deadline - now()
                if remaining <= 0:
                    return []
            epoch = getattr(self._tls, "epoch", None)
            # NOTE no retry= here: a broker-side get is a *leased* dequeue,
            # so a response frame lost with its connection only strands a
            # lease that expires and redelivers -- but an automatic
            # reconnect-resend would still fetch *different* envelopes
            # under a fresh lease while this caller believes it asked
            # once.  Surfacing the error keeps the failure visible; the
            # lease ledger (not a resend) is what makes it recoverable.
            header, blob = self._t.request(
                {"op": "get", "topic": self.topic, "kind": self.kind,
                 "max_n": max_n, "timeout": remaining,
                 "lease_timeout": self._t.lease_timeout,
                 "epoch": epoch})
            self._tls.epoch = header["epoch"]
            if header["envs"]:
                self._tls.held = header["lease"]
                out, off = [], 0
                for t_put, meta, n in header["envs"]:
                    out.append(Envelope(t_put, blob[off:off + n], meta))
                    off += n
                return out
            if not header["woken"]:
                return []                   # server-side timeout lapsed
            # woken (wake_all) or first-request epoch sync: re-check
            # cancel/deadline, then re-park with a current epoch

    def ack(self, flush: bool = False) -> None:
        held = getattr(self._tls, "held", None)
        if held is not None:
            self._tls.held = None
            self._t.queue_ack((self.topic, self.kind, held))
        if flush:
            self._t.flush_acks()

    def held_lease(self) -> Optional[int]:
        return getattr(self._tls, "held", None)

    def detach_lease(self) -> Optional[int]:
        held = getattr(self._tls, "held", None)
        self._tls.held = None
        return held

    def ack_lease(self, lease_id: Optional[int],
                  flush: bool = False) -> None:
        if lease_id is None:
            return
        self._t.queue_ack((self.topic, self.kind, lease_id))
        if flush:
            self._t.flush_acks()

    def renew(self, lease_id: Optional[int] = None) -> bool:
        """Heartbeat a lease (the holder's, or an explicit id handed to
        a heartbeat thread -- leases are addressed by (topic, kind, id),
        so any thread's connection can renew them).  Deliberately not
        retried: a renew that died on the wire just means the next
        heartbeat tick renews a little later."""
        lid = lease_id if lease_id is not None else self.held_lease()
        if lid is None:
            return False
        header, _ = self._t.request(
            {"op": "renew", "topic": self.topic, "kind": self.kind,
             "lease": lid})
        return header["ok"]

    def wake(self) -> None:
        self._t.wake_all()

    def __len__(self) -> int:
        header, _ = self._t.request(
            {"op": "len", "topic": self.topic, "kind": self.kind},
            retry=True)
        return header["n"]


class ProcTransport(Transport):
    name = "proc"

    def __init__(self, address: Optional[tuple] = None,
                 lease_timeout: float = 30.0,
                 snapshot_every: float = 0.0,
                 snapshot_path: Optional[str] = None):
        """address: connect to an existing broker (another process's
        fabric, or a cluster launcher's per-host federated broker); None
        forks a fresh broker owned by this transport.
        lease_timeout: seconds before an unacked get lease expires and
        its envelopes are redelivered; must exceed the longest consumer
        hold (a pool worker holds its lease for the task's execution)
        unless that consumer heartbeats via ``Channel.renew``.
        snapshot_every/snapshot_path: broker-side periodic auto-snapshot
        (atomic tmp+rename) -- crash protection with no application
        checkpoint call; only valid when this transport forks the
        broker (a remote broker configures its own)."""
        self._proc = None
        self._dir = None
        self._owner_pid = os.getpid()
        self.lease_timeout = lease_timeout
        self._pending_acks: list = []
        self._ack_lock = threading.Lock()
        if address is None:
            self._dir = tempfile.mkdtemp(prefix="colmena-broker-")
            sock, address = frames.make_server_socket(
                os.path.join(self._dir, "broker.sock"))
            self._proc = _mp.Process(
                target=broker_main,
                args=(sock, snapshot_every, snapshot_path),
                daemon=True, name="colmena-broker")
            self._proc.start()
            sock.close()                    # the broker child owns it now
            atexit.register(self.close)
        elif snapshot_every:
            raise ValueError(
                "snapshot_every configures the broker this transport forks;"
                " a remote broker's auto-snapshot is configured where it is"
                " launched (ClusterSpec.snapshot_every)")
        self.address = address
        self.client = frames.FrameClient(address)

    # -- ack piggybacking ---------------------------------------------------

    def queue_ack(self, ack: tuple) -> None:
        with self._ack_lock:
            self._pending_acks.append(ack)

    def flush_acks(self) -> None:
        """Force pending acks onto the wire now (normally they ride the
        next frame; use before exiting a consumer)."""
        with self._ack_lock:
            if not self._pending_acks:
                return
        self.request({"op": "ack"})

    def request(self, header: dict, payload: bytes = b"",
                retry: bool = False):
        """All broker traffic funnels through here so any frame can carry
        the pending acks.  On a failed send the acks are restored: they
        ride the next successful frame, and until then the leases just
        stay in-flight (expiry + claim dedup make that safe)."""
        acks = None
        with self._ack_lock:
            if self._pending_acks:
                acks = self._pending_acks
                self._pending_acks = []
        if acks:
            header = dict(header)
            header["acks"] = acks
        try:
            return self.client.request(header, payload, retry=retry)
        except (ConnectionError, OSError):
            if acks:
                with self._ack_lock:
                    self._pending_acks = acks + self._pending_acks
            raise

    # -- Transport interface ------------------------------------------------

    def channel(self, topic: str, kind: str) -> ProcChannel:
        return ProcChannel(self, topic, kind)

    def wake_all(self) -> None:
        try:
            self.request({"op": "wake"}, retry=True)
        except (ConnectionError, OSError):
            pass                    # broker already torn down: nothing parked

    def claim(self, task_id: str) -> bool:
        # deliberately NOT retried: a resend of a claim that was applied
        # before the connection died would answer False to the rightful
        # first claimant
        header, _ = self.request({"op": "claim", "id": task_id})
        return header["claimed"]

    def snapshot(self) -> bytes:
        _, payload = self.request({"op": "snapshot"}, retry=True)
        return payload

    def restore(self, data: bytes, expire_leases: bool = False) -> None:
        self.request({"op": "restore", "expire_leases": expire_leases},
                     data, retry=True)

    def close(self) -> None:
        # only the process that forked the broker may tear it down
        if self._proc is None or os.getpid() != self._owner_pid:
            return
        proc, self._proc = self._proc, None
        try:
            self.client.request({"op": "shutdown"})
        except (ConnectionError, OSError):
            pass
        self.client.close()
        proc.join(timeout=2)
        if proc.is_alive():
            proc.terminate()
        if self._dir is not None:
            import shutil
            shutil.rmtree(self._dir, ignore_errors=True)
