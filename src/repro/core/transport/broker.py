"""The broker process: owner of every per-topic request/result queue.

One broker serves all queue channels of a fabric over a single listening
socket.  Clients (Thinker process, Task Server intake threads, pool
workers) speak the frame protocol of ``frames.py``; the broker keeps a
``deque`` + ``Condition`` per (topic, kind) -- the same event-driven
structure as the local backend, just on the other side of a socket:

- ``put``  appends the sender's envelope bytes verbatim and notifies one
  parked getter (payloads are relayed, never unpickled).  A ``claim`` id
  in the header fuses an atomic first-completion claim with the enqueue:
  only the first claimant's envelope is published, so there is no window
  where an id is claimed but its result died with the claimant.
- ``get``  parks the connection's handler thread on the queue Condition
  until items arrive, the wake epoch bumps, or the timeout lapses; up to
  ``max_n`` envelopes come back concatenated in one response frame.
  The dequeue is **leased**, not destructive: the envelopes move to the
  queue's in-flight ledger under a lease id returned with the response,
  and only an ``ack`` deletes them.  An unacked lease (consumer death, a
  response frame lost with its connection) expires after its duration
  and the envelopes are requeued at the front -- parked getters bound
  their waits by the earliest lease deadline and run the expiry
  themselves, so redelivery needs no sweeper thread.
- ``ack``  releases leases.  Acks almost never arrive as their own
  frame: every request header may carry a piggybacked ``acks`` list that
  is applied before the op, so consumers commit their previous batch on
  the frame they were sending anyway.
- ``wake`` bumps every queue's epoch and notifies all -- pending gets
  return (possibly empty) so client-side cancel events propagate without
  any polling loop.
- ``claim`` is the standalone first-completion test-and-set (kept for
  callers that need arbitration without an enqueue; result publication
  uses the fused put-with-claim above).
- ``snapshot`` / ``restore`` serialize / replace the broker's whole
  state: queued + in-flight envelopes, lease durations (never wall-clock
  deadlines, so identical state gives identical bytes), wake epochs, and
  the claim window.  This is what campaign-level checkpointing rides on.

The listening socket is bound in the *parent* before forking the broker
process, so there is no readiness race: by the time the constructor
returns the address is connectable.
"""
from __future__ import annotations

import os
import socket as socketlib
import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro import observability as obs
from repro.core.transport import frames, shm
from repro.core.transport.base import (BoundedIdSet, dump_snapshot,
                                       load_snapshot)
from repro.utils.timing import now


class _BrokerQueue:
    def __init__(self):
        self.items: deque = deque()        # (t_put, meta, data)
        self.cond = threading.Condition()
        self.epoch = 0
        # lease_id -> (duration, deadline, [(t_put, meta, data), ...]);
        # all access under self.cond.  Lease ids are per-queue, so an ack
        # addresses (topic, kind, lease_id) and needs no broker-global
        # index (and no second lock on the get hot path).
        self.leases: Dict[int, Tuple[float, float, list]] = {}
        self.next_lease = 0


class Broker:
    def __init__(self, claim_window: int = 1 << 16,
                 shm_scope: Optional[str] = None):
        self._queues: Dict[Tuple[str, str], _BrokerQueue] = {}
        self._qlock = threading.Lock()
        self._claimed = BoundedIdSet(claim_window)
        self._claim_lock = threading.Lock()
        # preempted ids: written under _claim_lock (cancel, restore);
        # membership reads on hot paths are lock-free (GIL-atomic set
        # probes -- a racing cancel is caught at the next probe)
        self._cancelled = BoundedIdSet(claim_window)
        # the fabric's shared-memory scope token: advertised to clients
        # via the ``endpoints`` op so producers name their segments under
        # it (and teardown can sweep exactly this fabric's leftovers)
        self.shm_scope = shm_scope

    def _queue(self, topic: str, kind: str) -> _BrokerQueue:
        with self._qlock:
            q = self._queues.get((topic, kind))
            if q is None:
                q = self._queues[(topic, kind)] = _BrokerQueue()
            return q

    # -- lease plumbing (call with q.cond held) -----------------------------

    @staticmethod
    def _expire_locked(q: _BrokerQueue) -> None:
        if not q.leases:
            return
        tnow = now()
        expired = [lid for lid, (_, deadline, _) in q.leases.items()
                   if deadline <= tnow]
        if not expired:
            return
        obs.counter("expired_leases").inc(len(expired))
        for lid in expired:
            _, _, items = q.leases.pop(lid)
            obs.counter("redeliveries").inc(len(items))
            for t_put, meta, data in reversed(items):
                meta = dict(meta)
                meta["redelivered"] = meta.get("redelivered", 0) + 1
                q.items.appendleft((t_put, meta, data))
        q.cond.notify_all()

    @staticmethod
    def _next_lease_deadline_locked(q: _BrokerQueue) -> Optional[float]:
        if not q.leases:
            return None
        return min(deadline for _, deadline, _ in q.leases.values())

    # -- ops ----------------------------------------------------------------

    def put(self, topic: str, kind: str, t_put: float, meta: dict,
            data: bytes, claim: Optional[str] = None,
            shm_desc: Optional[dict] = None) -> bool:
        if shm_desc is not None:
            # the payload rides shared memory: ownership of the segment
            # transferred to this broker with the frame.  It is carried
            # in the envelope meta (so lease expiry redelivers it) and
            # unlinked when the envelope is destroyed (ack / rejected
            # claim / restore / shutdown).
            meta = dict(meta)
            meta["_shm"] = shm_desc
        q = self._queue(topic, kind)
        if claim is not None:
            # the claim lock is held ACROSS the enqueue (lock order:
            # claim_lock -> q.cond, same as snapshot) so a snapshot can
            # never capture the claim without its result -- that image
            # would dedup the redelivered re-execution and lose the task
            with self._claim_lock:
                if not self._claimed.claim(claim):
                    if shm_desc is not None:
                        shm.unlink_segment(shm_desc)
                    obs.counter("claim_rejects").inc()
                    return False            # duplicate publisher: swallowed
                with q.cond:
                    q.items.append((t_put, meta, data))
                    q.cond.notify()
            return True
        with q.cond:
            q.items.append((t_put, meta, data))
            q.cond.notify()
        return True

    def get(self, topic: str, kind: str, max_n: int,
            timeout: Optional[float], last_epoch: Optional[int],
            lease_timeout: float
            ) -> Tuple[List[tuple], bool, int, Optional[int]]:
        """Blocking batched leased drain.  Returns (items, woken, epoch,
        lease): ``woken`` tells the client an empty response came from a
        wake (re-check cancel and possibly re-park) rather than a
        timeout; ``lease`` is the id the client must ack once the batch
        is safely handed off (None when no items were returned).

        ``last_epoch`` is the wake epoch the client observed on its
        previous response (None on a channel's first request).  Parking
        only happens when the client's epoch is current, so a ``wake``
        that lands between the client's cancel check and this request
        is detected instead of lost -- the first request of a channel
        never parks (it syncs the epoch and returns woken), closing the
        race without any polling."""
        q = self._queue(topic, kind)
        deadline = None if timeout is None else now() + timeout
        with q.cond:
            self._expire_locked(q)
            if not q.items and (last_epoch is None
                                or q.epoch != last_epoch):
                return [], True, q.epoch, None  # epoch sync / missed wake
            out: list = []
            while not out:
                while not q.items:
                    if q.epoch != last_epoch:
                        return [], True, q.epoch, None
                    remaining = None
                    if deadline is not None:
                        remaining = deadline - now()
                        if remaining <= 0:
                            return [], False, q.epoch, None
                    # bound the park by the earliest in-flight lease
                    # deadline so this getter requeues expired leases
                    # itself
                    lease_dl = self._next_lease_deadline_locked(q)
                    if lease_dl is not None:
                        until_lease = max(lease_dl - now(), 0.0)
                        remaining = (until_lease if remaining is None
                                     else min(remaining, until_lease))
                    if remaining is None:
                        q.cond.wait()
                    else:
                        q.cond.wait(remaining)
                    self._expire_locked(q)
                while q.items and len(out) < max_n:
                    t_put, meta, data = q.items.popleft()
                    tid = meta.get("task_id")
                    if tid is not None and tid in self._cancelled:
                        # cancelled work drained defensively (a retry
                        # requeue or lease-expiry redelivery raced the
                        # cancel's strip): destroy it.  Rare path, so
                        # the unlink may stay under the cond
                        if "_shm" in meta:
                            shm.unlink_segment(meta["_shm"])
                        continue
                    out.append((t_put, meta, data))
            lid = q.next_lease
            q.next_lease += 1
            # `out` is owned by this handler and never mutated after the
            # response is built: the ledger can share it (no copy)
            q.leases[lid] = (lease_timeout, now() + lease_timeout, out)
            if len(q.leases) == 1:
                # empty -> non-empty lease transition: getters parked
                # before any lease existed wait *unbounded* (or until
                # their own deadline) -- wake them so they re-arm their
                # park bounded by this lease's expiry, otherwise nobody
                # would ever run the expiry that redelivers it
                q.cond.notify_all()
            return out, False, q.epoch, lid

    def ack(self, topic: str, kind: str, lease_id: int) -> None:
        q = self._queue(topic, kind)
        with q.cond:
            lease = q.leases.pop(lease_id, None)    # already expired: no-op
        if lease is not None:
            # acked envelopes are destroyed: release their segments (the
            # unlink happens outside the queue lock; the items are no
            # longer reachable from any queue structure)
            for _, meta, _ in lease[2]:
                if "_shm" in meta:
                    shm.unlink_segment(meta["_shm"])

    def backup(self, topic: str, kind: str, lease_id: int, task_id: str,
               meta_update: dict) -> bool:
        """Straggler support for the direct-subscription data plane: the
        pool parent never sees envelope bytes any more, but the broker
        holds the leased original right here -- so a backup is a
        broker-side *clone* of the leased envelope back onto the queue,
        with placement metadata (``exclude_host``/``exclude_worker``)
        merged into the copy's meta.  The original lease is untouched
        (the slow worker may still win); first completion arbitrates
        through the claim as always.  False = the lease is gone (acked
        or expired -- either way a backup is moot)."""
        q = self._queue(topic, kind)
        with q.cond:
            lease = q.leases.get(lease_id)
            if lease is None:
                return False
            for t_put, meta, data in lease[2]:
                if meta.get("task_id") == task_id:
                    m = dict(meta)
                    m.update(meta_update)
                    m["backup"] = True
                    if "_shm" in m:
                        # the clone cannot share the original's segment
                        # (each envelope's destruction unlinks its own):
                        # inline the payload into the copy instead
                        try:
                            data = shm.read_segment(m.pop("_shm"))
                        except OSError:
                            return False
                    q.items.append((t_put, m, data))
                    q.cond.notify()
                    obs.counter("backup_clones").inc()
                    return True
        return False

    def renew(self, topic: str, kind: str, lease_id: int) -> bool:
        """Push a live lease's deadline out by another full duration.
        False = the lease is gone (acked, or expired and requeued): the
        renewal lost the race and the holder's eventual completion will
        arbitrate through the claim like any straggler backup.  Getters
        parked against the old deadline simply wake, find nothing
        expired, and re-bound against the new one."""
        q = self._queue(topic, kind)
        with q.cond:
            lease = q.leases.get(lease_id)
            if lease is None:
                return False
            dur, _, items = lease
            q.leases[lease_id] = (dur, now() + dur, items)
            return True

    def wake(self) -> None:
        with self._qlock:
            queues = list(self._queues.values())
        for q in queues:
            with q.cond:
                q.epoch += 1
                q.cond.notify_all()

    def claim(self, task_id: str) -> bool:
        with self._claim_lock:
            return self._claimed.claim(task_id)

    def cancel(self, topic: str, task_id: str) -> bool:
        """Preempt ``task_id`` on ``topic``: claim the id (a racing
        completion's fused put-claim dedups against this -- exactly one
        of cancel/complete wins), record it cancelled, destroy every
        queued copy (original, retry requeue, straggler backup clone)
        and strip it out of live leases on the requests *and* stream
        queues, then wake parked getters so the freed capacity is
        re-steered immediately.  The executing worker is not contacted
        here -- it notices via the fused ``put_stream`` reply or the
        heartbeat's ``is_cancelled`` probe and aborts cooperatively."""
        # resolve the queues BEFORE taking the claim lock: _queue
        # acquires _qlock, and a claim_lock -> qlock nesting would be a
        # new lock-order edge nothing else needs
        qs = [self._queue(topic, "requests"), self._queue(topic, "stream")]
        dropped: list = []
        # claim + cancelled-window write + strip are one atomic step
        # under the claim lock (claim_lock -> q.cond, the same order as
        # put-with-claim and snapshot): a snapshot can never image the
        # claim without the strip
        with self._claim_lock:
            if not self._claimed.claim(task_id):
                return False                # completion already won
            self._cancelled.add(task_id)
            for q in qs:
                with q.cond:
                    kept: deque = deque()
                    for item in q.items:
                        if item[1].get("task_id") == task_id:
                            if "_shm" in item[1]:
                                dropped.append(item[1]["_shm"])
                        else:
                            kept.append(item)
                    q.items = kept
                    for lid in list(q.leases):
                        dur, dl, items = q.leases[lid]
                        live = []
                        for item in items:
                            if item[1].get("task_id") == task_id:
                                if "_shm" in item[1]:
                                    dropped.append(item[1]["_shm"])
                            else:
                                live.append(item)
                        if len(live) == len(items):
                            continue
                        if live:
                            q.leases[lid] = (dur, dl, live)
                        else:
                            # nothing left under the lease (e.g. a
                            # backup clone's whole delivery): drop it --
                            # expiry would requeue nothing
                            del q.leases[lid]
                    # wake parked getters: an idle getter parked in an
                    # unbounded wait re-checks its cancel Event (the
                    # PR-7 stop-envelope hazard) and freed capacity is
                    # re-steerable immediately
                    q.epoch += 1
                    q.cond.notify_all()
        # revocation must unlink, not leak: the stripped envelopes owned
        # their segments (outside the locks, mirroring ack)
        for desc in dropped:
            shm.unlink_segment(desc)
        obs.counter("tasks_cancelled").inc()
        return True

    def put_stream(self, topic: str, t_put: float, meta: dict,
                   data: bytes) -> bool:
        """Mid-task observation publish fused with the cancel probe:
        True = the task is already cancelled and the observation was
        dropped (the worker's cue to abort); False = enqueued on the
        stream lane.  The membership read is lock-free (GIL-atomic; a
        cancel racing this publish is benign -- the worker aborts at its
        next probe and the get path destroys the stale observation)."""
        tid = meta.get("task_id")
        if tid is not None and tid in self._cancelled:
            obs.counter("observations_dropped").inc()
            return True
        q = self._queue(topic, "stream")
        with q.cond:
            q.items.append((t_put, meta, data))
            q.cond.notify()
        return False

    def is_cancelled(self, task_id: str) -> bool:
        """Read-only probe of the cancelled window (idempotent)."""
        return task_id in self._cancelled   # GIL-atomic read

    def qlen(self, topic: str, kind: str) -> int:
        q = self._queue(topic, kind)
        with q.cond:
            self._expire_locked(q)
            return len(q.items)

    def scrape_stats(self) -> dict:
        """The ``stats_scrape`` reply body: per-queue depth and in-flight
        lease counts read live under each queue's own lock, the shm
        segment count derived from envelope metas, plus this process's
        cumulative metrics registry (expiry/claim-reject/backup
        counters).  Read-only and idempotent by construction."""
        with self._qlock:
            queues = sorted(self._queues.items())
        depth: Dict[str, int] = {}
        inflight: Dict[str, int] = {}
        segs = 0
        for (topic, kind), q in queues:
            key = f"{topic}/{kind}"
            with q.cond:
                self._expire_locked(q)
                depth[key] = len(q.items)
                leased = [it for _, _, items in q.leases.values()
                          for it in items]
                inflight[key] = len(leased)
                segs += sum(1 for _, meta, _ in q.items if "_shm" in meta)
                segs += sum(1 for _, meta, _ in leased if "_shm" in meta)
        obs.gauge("queue_depth").set(sum(depth.values()))
        obs.gauge("inflight_leases").set(sum(inflight.values()))
        obs.gauge("shm_segments").set(segs)
        return {"t": now(), "pid": os.getpid(),
                "machine": socketlib.gethostname(),
                "queue_depth": depth, "inflight_leases": inflight,
                "shm_segments": segs, "metrics": obs.metrics_snapshot()}

    # -- shared-memory plumbing ----------------------------------------------

    @staticmethod
    def _inline_shm(item: tuple) -> tuple:
        """Snapshot form of a queue item: segment payloads are read back
        inline and the descriptor dropped, so a snapshot is self-contained
        (restorable into a fresh incarnation whose segments are gone) and
        byte-identical across resnaps of identical state (segment names
        are incarnation-local and must not leak into the image)."""
        t_put, meta, data = item
        if "_shm" not in meta:
            return item
        meta = dict(meta)
        data = shm.read_segment(meta.pop("_shm"))
        return (t_put, meta, data)

    def release_segments(self) -> None:
        """Unlink every segment still referenced by a queue or lease --
        the graceful-shutdown path (a SIGKILLed broker's leftovers are
        reclaimed by the owner transport's scope sweep instead)."""
        with self._qlock:
            queues = list(self._queues.values())
        for q in queues:
            with q.cond:
                items = list(q.items)
                for _, _, lease_items in q.leases.values():
                    items.extend(lease_items)
            for _, meta, _ in items:
                if "_shm" in meta:
                    shm.unlink_segment(meta["_shm"])

    # -- snapshot/restore -----------------------------------------------------

    def snapshot(self) -> bytes:
        """A *consistent global cut*: the claim lock plus every queue
        Condition are held simultaneously (acquired in the same sorted
        order everywhere, claim lock first -- matching put-with-claim's
        claim_lock -> cond order), so no envelope mid-relay between two
        queues and no claim-fused publish can straddle the image.  An
        envelope captured in two queues (leased upstream and already
        relayed downstream) merely re-executes into the claim dedup;
        captured in neither would be a lost task, and cannot happen."""
        from contextlib import ExitStack
        with self._qlock:
            queues = sorted(self._queues.items())
        with ExitStack() as stack:
            stack.enter_context(self._claim_lock)
            for _, q in queues:
                stack.enter_context(q.cond)
            out = []
            for (topic, kind), q in queues:
                items = [self._inline_shm(it) for it in q.items]
                leases = sorted((lid, dur,
                                 [self._inline_shm(it) for it in lease_items])
                                for lid, (dur, _, lease_items)
                                in q.leases.items())
                out.append((topic, kind, q.epoch, items, leases))
            order = list(self._claimed._order)
            maxlen = self._claimed.maxlen
            c_order = list(self._cancelled._order)
            c_maxlen = self._cancelled.maxlen
        return dump_snapshot(out, maxlen, order, c_maxlen, c_order)

    def restore(self, data: bytes, expire_leases: bool = False) -> None:
        state = load_snapshot(data)
        # the restored image replaces the current queues wholesale: any
        # segment the discarded envelopes referenced is released first
        self.release_segments()
        tnow = now()
        for topic, kind, epoch, items, leases in state["queues"]:
            q = self._queue(topic, kind)
            with q.cond:
                q.items = deque(items)
                q.epoch = epoch
                # deadline = tnow when expiring: the holders died with the
                # previous incarnation, so the expiry below requeues now
                q.leases = {lid: (dur, tnow if expire_leases else tnow + dur,
                                  list(lease_items))
                            for lid, dur, lease_items in leases}
                if q.leases:
                    q.next_lease = max(q.leases) + 1
                if expire_leases:
                    self._expire_locked(q)
                q.cond.notify_all()
        with self._claim_lock:
            claimed = BoundedIdSet(state["claims"]["maxlen"])
            for cid in state["claims"]["order"]:
                claimed.add(cid)
            self._claimed = claimed
            # a cancelled id must stay cancelled across resume: restored
            # stale envelopes of preempted tasks are destroyed on get
            canc = state.get("cancelled")
            if canc:
                cancelled = BoundedIdSet(canc["maxlen"]
                                         or self._cancelled.maxlen)
                for cid in canc["order"]:
                    cancelled.add(cid)
                self._cancelled = cancelled

    # -- frame dispatch -------------------------------------------------------

    def handle(self, header: dict, payload: bytes
               ) -> Optional[Tuple[dict, bytes]]:
        # piggybacked acks commit the sender's previous batches before
        # the op itself runs (so a put that triggers redelivery can never
        # race ahead of the ack it travelled with)
        for topic, kind, lid in header.get("acks", ()):
            self.ack(topic, kind, lid)
        op = header["op"]
        if op == "put":
            ok = self.put(header["topic"], header["kind"], header["t_put"],
                          header["meta"], payload, header.get("claim"),
                          header.get("shm"))
            return {"ok": True, "claimed": ok}, b""
        if op == "get":
            items, woken, epoch, lease = self.get(
                header["topic"], header["kind"], header["max_n"],
                header["timeout"], header.get("epoch"),
                header.get("lease_timeout", 30.0))
            shm_ok = header.get("shm_ok", False)
            t_grant = now()
            lens, blobs = [], []
            for t_put, meta, data in items:
                if meta.get("trace") and meta.get("task_id"):
                    # queue_wait bounds enqueue -> lease grant on THIS
                    # broker's clock; t_put is the producer's clock (same
                    # CLOCK_MONOTONIC timebase on one machine, aligned by
                    # the report's offset chain across machines)
                    obs.span(meta["task_id"], "queue_wait", t_put, t_grant,
                             attempt=int(meta.get("redelivered", 0) or 0),
                             topic=header["topic"], kind=header["kind"])
                if "_shm" in meta and shm_ok:
                    # hand the descriptor through: the co-located consumer
                    # maps the segment itself and the payload never touches
                    # this socket.  The lease keeps the descriptor, so the
                    # eventual ack (or a post-expiry redelivery) still
                    # resolves the segment's lifetime here.
                    lens.append((t_put, meta, 0))
                    continue
                if "_shm" in meta:
                    # remote (or lane-disabled) consumer: inline the bytes;
                    # the leased original keeps the descriptor for cleanup
                    meta = dict(meta)
                    data = shm.read_segment(meta.pop("_shm"))
                lens.append((t_put, meta, len(data)))
                blobs.append(data)
            return {"envs": lens, "woken": woken, "epoch": epoch,
                    "lease": lease}, b"".join(blobs)
        if op == "backup":
            ok = self.backup(header["topic"], header["kind"], header["lease"],
                             header["id"], header["meta"])
            return {"ok": ok}, b""
        if op == "endpoints":
            # data-plane discovery: a plain broker IS every topic's home
            # (no peers to advertise); the federation overrides this with
            # its peer address map so clients dial home brokers directly
            return {"host": None, "peers": {}, "partition": {},
                    "machine": socketlib.gethostname(),
                    "scope": self.shm_scope}, b""
        if op == "ack":                     # explicit flush (rare path)
            return {"ok": True}, b""
        if op == "renew":
            ok = self.renew(header["topic"], header["kind"], header["lease"])
            return {"ok": ok}, b""
        if op == "wake":
            self.wake()
            return {"ok": True}, b""
        if op == "claim":
            return {"claimed": self.claim(header["id"])}, b""
        if op == "cancel":
            return {"won": self.cancel(header["topic"], header["id"])}, b""
        if op == "put_stream":
            dropped = self.put_stream(header["topic"], header["t_put"],
                                      header["meta"], payload)
            return {"ok": True, "cancelled": dropped}, b""
        if op == "cancelled":
            return {"cancelled": self.is_cancelled(header["id"])}, b""
        if op == "len":
            return {"n": self.qlen(header["topic"], header["kind"])}, b""
        if op == "snapshot":
            return {"ok": True}, self.snapshot()
        if op == "restore":
            self.restore(payload, header.get("expire_leases", False))
            return {"ok": True}, b""
        if op == "ping":
            return {"ok": True}, b""
        if op == "clock_sync":
            # read-only clock probe: the caller brackets this reply with
            # its own now() pair and min-RTT-midpoints the offset
            return {"t": now()}, b""
        if op == "stats_scrape":
            return {"stats": self.scrape_stats()}, b""
        if op == "shutdown":
            return None
        return {"error": f"unknown op {op!r}"}, b""


def start_autosnapshot(snapshot_fn, every: float, path: str,
                       stop: threading.Event) -> threading.Thread:
    """Periodic broker-side crash protection: every ``every`` seconds,
    write ``snapshot_fn()`` to ``path`` atomically (tmp + rename, so a
    kill mid-write leaves the previous image intact).  Campaigns get a
    resumable file without any application-level checkpoint call --
    ``ColmenaQueues.load_checkpoint`` recognizes the raw snapshot format
    and derives the active-task count from the envelope metas.  A failed
    write is logged-by-omission (the next tick retries); it must never
    take the broker down with it."""
    import os

    def loop():
        while not stop.wait(every):
            try:
                data = snapshot_fn()
                tmp = path + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, path)
            except Exception:               # noqa: BLE001
                pass

    th = threading.Thread(target=loop, daemon=True, name="broker-autosnap")
    th.start()
    return th


def broker_main(sock, snapshot_every: float = 0.0,
                snapshot_path: Optional[str] = None,
                shm_scope: Optional[str] = None) -> None:
    """Entry point of the broker process (listening socket inherited from
    the parent fork)."""
    try:
        addr = obs.addr_str(sock.getsockname())
    except OSError:
        addr = ""
    obs.configure(role="broker", addr=addr)
    broker = Broker(shm_scope=shm_scope)
    stop = threading.Event()
    if snapshot_every and snapshot_path:
        start_autosnapshot(broker.snapshot, snapshot_every, snapshot_path,
                           stop)
    frames.serve_forever(sock, broker.handle, stop)
    broker.release_segments()
    # graceful shutdown: final cumulative metrics + buffered span tail
    obs.flush_metrics(force=True)
