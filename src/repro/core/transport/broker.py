"""The broker process: owner of every per-topic request/result queue.

One broker serves all queue channels of a fabric over a single listening
socket.  Clients (Thinker process, Task Server intake threads, pool
workers) speak the frame protocol of ``frames.py``; the broker keeps a
``deque`` + ``Condition`` per (topic, kind) -- the same event-driven
structure as the local backend, just on the other side of a socket:

- ``put``  appends the sender's envelope bytes verbatim and notifies one
  parked getter (payloads are relayed, never unpickled).
- ``get``  parks the connection's handler thread on the queue Condition
  until items arrive, the wake epoch bumps, or the timeout lapses; up to
  ``max_n`` envelopes come back concatenated in one response frame.
- ``wake`` bumps every queue's epoch and notifies all -- pending gets
  return (possibly empty) so client-side cancel events propagate without
  any polling loop.
- ``claim`` is an atomic first-completion test-and-set used by worker
  pools to dedup straggler-race duplicates across processes (bounded
  window, mirroring the in-process Task Server's ``_BoundedIdSet``).

The listening socket is bound in the *parent* before forking the broker
process, so there is no readiness race: by the time the constructor
returns the address is connectable.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.transport import frames
from repro.core.transport.base import BoundedIdSet
from repro.utils.timing import now


class _BrokerQueue:
    def __init__(self):
        self.items: deque = deque()        # (t_put, meta, data)
        self.cond = threading.Condition()
        self.epoch = 0


class Broker:
    def __init__(self, claim_window: int = 1 << 16):
        self._queues: Dict[Tuple[str, str], _BrokerQueue] = {}
        self._qlock = threading.Lock()
        self._claimed = BoundedIdSet(claim_window)
        self._claim_lock = threading.Lock()

    def _queue(self, topic: str, kind: str) -> _BrokerQueue:
        with self._qlock:
            q = self._queues.get((topic, kind))
            if q is None:
                q = self._queues[(topic, kind)] = _BrokerQueue()
            return q

    # -- ops ----------------------------------------------------------------

    def put(self, topic: str, kind: str, t_put: float, meta: dict,
            data: bytes) -> None:
        q = self._queue(topic, kind)
        with q.cond:
            q.items.append((t_put, meta, data))
            q.cond.notify()

    def get(self, topic: str, kind: str, max_n: int,
            timeout: Optional[float], last_epoch: Optional[int]
            ) -> Tuple[List[tuple], bool, int]:
        """Blocking batched drain.  Returns (items, woken, epoch): ``woken``
        tells the client an empty response came from a wake (re-check
        cancel and possibly re-park) rather than a timeout.

        ``last_epoch`` is the wake epoch the client observed on its
        previous response (None on a channel's first request).  Parking
        only happens when the client's epoch is current, so a ``wake``
        that lands between the client's cancel check and this request
        is detected instead of lost -- the first request of a channel
        never parks (it syncs the epoch and returns woken), closing the
        race without any polling."""
        q = self._queue(topic, kind)
        deadline = None if timeout is None else now() + timeout
        with q.cond:
            if not q.items and (last_epoch is None
                                or q.epoch != last_epoch):
                return [], True, q.epoch    # epoch sync / missed wake
            while not q.items:
                if q.epoch != last_epoch:
                    return [], True, q.epoch
                if deadline is None:
                    q.cond.wait()
                else:
                    remaining = deadline - now()
                    if remaining <= 0:
                        return [], False, q.epoch
                    q.cond.wait(remaining)
            out = []
            while q.items and len(out) < max_n:
                out.append(q.items.popleft())
            return out, False, q.epoch

    def wake(self) -> None:
        with self._qlock:
            queues = list(self._queues.values())
        for q in queues:
            with q.cond:
                q.epoch += 1
                q.cond.notify_all()

    def claim(self, task_id: str) -> bool:
        with self._claim_lock:
            return self._claimed.claim(task_id)

    def qlen(self, topic: str, kind: str) -> int:
        q = self._queue(topic, kind)
        with q.cond:
            return len(q.items)

    # -- frame dispatch -------------------------------------------------------

    def handle(self, header: dict, payload: bytes
               ) -> Optional[Tuple[dict, bytes]]:
        op = header["op"]
        if op == "put":
            self.put(header["topic"], header["kind"], header["t_put"],
                     header["meta"], payload)
            return {"ok": True}, b""
        if op == "get":
            items, woken, epoch = self.get(
                header["topic"], header["kind"], header["max_n"],
                header["timeout"], header.get("epoch"))
            lens, blobs = [], []
            for t_put, meta, data in items:
                lens.append((t_put, meta, len(data)))
                blobs.append(data)
            return {"envs": lens, "woken": woken,
                    "epoch": epoch}, b"".join(blobs)
        if op == "wake":
            self.wake()
            return {"ok": True}, b""
        if op == "claim":
            return {"claimed": self.claim(header["id"])}, b""
        if op == "len":
            return {"n": self.qlen(header["topic"], header["kind"])}, b""
        if op == "ping":
            return {"ok": True}, b""
        if op == "shutdown":
            return None
        return {"error": f"unknown op {op!r}"}, b""


def broker_main(sock) -> None:
    """Entry point of the broker process (listening socket inherited from
    the parent fork)."""
    broker = Broker()
    frames.serve_forever(sock, broker.handle, threading.Event())
