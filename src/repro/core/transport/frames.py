"""Length-prefixed frame protocol shared by the broker and VS shards.

Frame layout::

    uint32 header_len (big-endian) | header (pickled dict) | payload bytes

The header is a *small* control dict (op name, topic, sizes); the payload
is opaque bytes appended verbatim -- for queue ops it is the message's
single pickle, so servers relay it without ever deserializing it.  The
header carries ``plen`` (payload length) so one recv loop reads exactly
one frame.

``FrameClient`` keeps one socket per (process, thread): a blocked ``get``
occupies its connection server-side, so concurrent client threads each get
their own; after a ``fork`` the inherited sockets are abandoned (keyed by
pid) and fresh connections are opened lazily -- this is what makes the
client objects safe to capture in forked worker processes.
"""
from __future__ import annotations

import os
import pickle
import socket
import struct
import threading
from typing import Callable, Optional, Tuple

_LEN = struct.Struct(">I")


# ---------------------------------------------------------------------------
# Frame IO
# ---------------------------------------------------------------------------


def send_frame(sock: socket.socket, header: dict, payload: bytes = b"") -> None:
    header = dict(header)
    header["plen"] = len(payload)
    hbytes = pickle.dumps(header, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(hbytes)) + hbytes + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    parts = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed")
        parts.append(chunk)
        n -= len(chunk)
    return b"".join(parts)


def recv_frame(sock: socket.socket) -> Tuple[dict, bytes]:
    hlen = _LEN.unpack(_recv_exact(sock, _LEN.size))[0]
    header = pickle.loads(_recv_exact(sock, hlen))
    payload = _recv_exact(sock, header["plen"]) if header["plen"] else b""
    return header, payload


# ---------------------------------------------------------------------------
# Addresses: prefer Unix-domain sockets, fall back to loopback TCP
# ---------------------------------------------------------------------------


def make_server_socket(path_hint: str, tcp: bool = False,
                       host: str = "127.0.0.1") -> Tuple[socket.socket, tuple]:
    """Bind a listening socket; returns (sock, address) where address is
    ("unix", path) or ("tcp", host, port).  ``tcp=True`` skips the
    Unix-domain preference -- cluster deployments need an address a
    process on another (possibly simulated) host can dial."""
    if not tcp and hasattr(socket, "AF_UNIX"):
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            sock.bind(path_hint)
            sock.listen(128)
            return sock, ("unix", path_hint)
        except OSError:
            sock.close()
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.bind((host, 0))
    sock.listen(128)
    return sock, ("tcp", host, sock.getsockname()[1])


def connect(address: tuple) -> socket.socket:
    if address[0] == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.connect(address[1])
    else:
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.connect((address[1], address[2]))
    return sock


# ---------------------------------------------------------------------------
# Client: one lazily-opened socket per (pid, thread); one request in flight
# ---------------------------------------------------------------------------


class FrameClient:
    def __init__(self, address: tuple):
        self.address = address
        self._tls = threading.local()
        self._pid = os.getpid()

    def _sock(self) -> socket.socket:
        # after fork: inherited sockets are shared with the parent; abandon
        # them and reconnect in the child
        if os.getpid() != self._pid:
            self._pid = os.getpid()
            self._tls = threading.local()
        sock = getattr(self._tls, "sock", None)
        if sock is None:
            sock = self._tls.sock = connect(self.address)
        return sock

    def request(self, header: dict, payload: bytes = b"",
                retry: bool = False) -> Tuple[dict, bytes]:
        """retry: reconnect-and-resend once on a dropped connection.  Only
        set it for ops declared idempotent in
        ``repro.analysis.idempotent_ops.IDEMPOTENT_OPS`` (each entry
        carries the one-line justification; the module docstring argues
        the deliberate exclusions -- get, claim, put, renew, ack).  The
        ``idempotent-retry-registry`` fabriclint pass enforces this at
        every call site.  A response carrying an ``error`` header
        (server-side handler exception) is raised here as RuntimeError."""
        sock = self._sock()
        try:
            send_frame(sock, header, payload)
            resp = recv_frame(sock)
        except (ConnectionError, OSError):
            self._tls.sock = None
            if not retry:
                raise
            sock = self._sock()
            send_frame(sock, header, payload)
            resp = recv_frame(sock)
        if "error" in resp[0]:
            raise RuntimeError(
                f"{header.get('op')} failed server-side: {resp[0]['error']}")
        return resp

    def probe(self, timeout: float = 1.0) -> bool:
        """Liveness check on a *fresh* connection (the cached per-thread
        socket is left alone): dial, ping, and answer within ``timeout``.
        Used by shard rebalancing to decide whether a departing member
        can still be drained or must be rebuilt from its replicas -- a
        blocked cached socket must not make a live shard look dead."""
        try:
            sock = connect(self.address)
        except OSError:
            return False
        try:
            sock.settimeout(timeout)
            send_frame(sock, {"op": "ping"})
            recv_frame(sock)
            return True
        except (OSError, ConnectionError):
            return False
        finally:
            try:
                sock.close()
            except OSError:
                pass

    def close(self) -> None:
        sock = getattr(self._tls, "sock", None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
            self._tls.sock = None


# ---------------------------------------------------------------------------
# Server: accept loop + one handler thread per connection
# ---------------------------------------------------------------------------


def serve_forever(sock: socket.socket,
                  handle: Callable[[dict, bytes], Optional[Tuple[dict, bytes]]],
                  stop: threading.Event) -> None:
    """Blocking accept loop.  ``handle(header, payload)`` returns the
    response ``(header, payload)`` -- it may block (e.g. a queue get), which
    only parks that connection's thread.  Returning None shuts the server
    down (after acking the requester)."""

    def conn_loop(conn: socket.socket) -> None:
        try:
            while not stop.is_set():
                header, payload = recv_frame(conn)
                try:
                    out = handle(header, payload)
                except Exception as e:                 # noqa: BLE001
                    # a handler error must not kill the connection: report
                    # it in-band so the client can raise it at the caller
                    send_frame(conn, {"error": f"{e!r}"})
                    continue
                if out is None:
                    send_frame(conn, {"ok": True})
                    stop.set()
                    # unblock the accept loop
                    try:
                        connect_addr = sock.getsockname()
                        if sock.family == getattr(socket, "AF_UNIX", None):
                            connect(("unix", connect_addr)).close()
                        else:
                            connect(("tcp", connect_addr[0],
                                     connect_addr[1])).close()
                    except OSError:
                        pass
                    return
                send_frame(conn, out[0], out[1])
        except (ConnectionError, OSError):
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    while not stop.is_set():
        try:
            conn, _ = sock.accept()
        except OSError:
            return
        threading.Thread(target=conn_loop, args=(conn,), daemon=True).start()
