"""BaseThinker: multi-agent decision processes (paper §III-B1, Listing 1).

A Thinker subclass defines its policy as decorated methods:

    class MyThinker(BaseThinker):
        @agent
        def planner(self):
            ...                        # runs as a thread after .run()

        @result_processor(topic="simulate")
        def consumer(self, result):
            ...                        # called for every completed result

        @event_responder(event="model_updated")
        def rescore(self):
            ...                        # runs each time the event is set

``run()`` launches every agent as a thread and joins them when ``done`` is
set.  Agents communicate with the Task Server via ``self.queues`` and with
each other through shared state + ``self.events`` (threading primitives,
exactly as in the paper).

All agent threads are event-driven: result processors park inside the
queue's Condition until a result (or shutdown) arrives, and event
responders wait on a shared condition hub that both their event and
``done`` notify -- setting ``done`` wakes every thread immediately instead
of waiting out a poll interval.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Optional

from repro.core.queues import ColmenaQueues
from repro.core.resources import ResourceTracker


def agent(fn):
    fn._colmena_agent = {"kind": "agent"}
    return fn


def result_processor(topic: str = "default"):
    def deco(fn):
        fn._colmena_agent = {"kind": "result_processor", "topic": topic}
        return fn
    return deco


def event_responder(event: str):
    def deco(fn):
        fn._colmena_agent = {"kind": "event_responder", "event": event}
        return fn
    return deco


class HubEvent(threading.Event):
    """Event that notifies a shared Condition (and optional wakers) on set,
    so one thread can wait for *any* of several events without polling."""

    def __init__(self, cond: threading.Condition, wakers=()):
        super().__init__()
        self._cond = cond
        self._wakers = list(wakers)

    def set(self) -> None:
        super().set()
        with self._cond:
            self._cond.notify_all()
        for fn in self._wakers:
            fn()


class BaseThinker:
    def __init__(self, queues: ColmenaQueues,
                 resources: Optional[ResourceTracker] = None):
        self.queues = queues
        self.resources = resources
        self._hub = threading.Condition()
        # done wakes every parked agent: hub waiters AND queue consumers
        self.done = HubEvent(self._hub, wakers=[queues.wake_all])
        self.events: dict = defaultdict(lambda: HubEvent(self._hub))
        self._threads: list = []
        self.logger_lines: list = []

    # -- helpers ---------------------------------------------------------------

    def log(self, text: str) -> None:
        self.logger_lines.append(text)

    def set_event(self, name: str) -> None:
        self.events[name].set()

    # -- execution ---------------------------------------------------------------

    def _agent_methods(self):
        for name in dir(self):
            fn = getattr(self, name)
            meta = getattr(fn, "_colmena_agent", None)
            if meta is not None:
                yield fn, meta

    def run(self, timeout: Optional[float] = None) -> None:
        for fn, meta in self._agent_methods():
            if meta["kind"] == "agent":
                target = self._wrap_agent(fn)
            elif meta["kind"] == "result_processor":
                target = self._wrap_processor(fn, meta["topic"])
            else:
                target = self._wrap_responder(fn, meta["event"])
            th = threading.Thread(target=target, daemon=True,
                                  name=f"thinker-{fn.__name__}")
            th.start()
            self._threads.append(th)
        if (type(self).process_intermediate
                is not BaseThinker.process_intermediate):
            # the subclass consumes the stream lane: one drain thread per
            # worker topic (mirrors result processors -- parked in the
            # stream queue's Condition, woken by done via wake_all)
            for topic in self.queues.topics():
                th = threading.Thread(
                    target=self._wrap_stream(topic), daemon=True,
                    name=f"thinker-stream-{topic}")
                th.start()
                self._threads.append(th)
        self.done.wait(timeout)
        self.done.set()                 # timeout also terminates processors
        for th in self._threads:
            th.join(timeout=5)

    def _wrap_agent(self, fn):
        def run_agent():
            try:
                fn()
            except Exception as e:                     # noqa: BLE001
                self.log(f"agent {fn.__name__} crashed: {e!r}")
                self.done.set()
        return run_agent

    def _wrap_processor(self, fn, topic):
        def run_processor():
            while not self.done.is_set():
                # blocks until results arrive; done.set() wakes it.  The
                # batched drain hands one wakeup several completed results
                # when the processor thread is the bottleneck (fig5): the
                # per-result queue handshake is amortized across the batch.
                # Once done is set, the rest of the batch is discarded --
                # the same fate results still sitting in the queue have
                # always had (a Thinker that sets done at a threshold,
                # e.g. Listing 1, processes exactly its target count).
                results = self.queues.get_results(topic, max_n=32,
                                                  cancel=self.done)
                for result in results:
                    if self.done.is_set():
                        break
                    try:
                        fn(result)
                    except Exception as e:             # noqa: BLE001
                        self.log(f"processor {fn.__name__} crashed: {e!r}")
                        self.done.set()
                if results and not self.done.is_set():
                    try:
                        self.after_result_batch(topic)
                    except Exception as e:             # noqa: BLE001
                        self.log(f"after_result_batch crashed: {e!r}")
                        self.done.set()
        return run_processor

    def _wrap_stream(self, topic):
        def run_stream():
            while not self.done.is_set():
                obs_batch = self.queues.get_intermediates(topic, max_n=32,
                                                          cancel=self.done)
                for ob in obs_batch:
                    if self.done.is_set():
                        break
                    try:
                        self.process_intermediate(ob)
                    except Exception as e:             # noqa: BLE001
                        self.log(f"process_intermediate crashed: {e!r}")
                        self.done.set()
        return run_stream

    def process_intermediate(self, observation) -> None:
        """Streaming-steering hook: called with every
        ``message.Intermediate`` a worker publishes mid-task via
        ``streaming.report_intermediate``.  Override it to rank partial
        results and ``self.queues.cancel(observation.task_id, topic)``
        losers early -- the freed capacity re-steers immediately.  The
        default is a no-op and, when not overridden, no stream drain
        threads are started at all (zero cost for non-streaming
        Thinkers)."""

    def after_result_batch(self, topic: str) -> None:
        """Hook called after a drained result batch is fully processed.
        This is the safe place to take a fabric checkpoint
        (``queues.checkpoint``): every result of the batch -- whose
        delivery lease was committed when the batch was decoded -- has
        been counted by the processor, so the application progress
        written into the checkpoint agrees with the captured queues.  A
        checkpoint taken *mid*-batch would record decoded-but-unprocessed
        results nowhere (acked out of the broker, absent from the
        progress counters) and lose them across a resume."""

    def _wrap_responder(self, fn, event):
        def run_responder():
            ev = self.events[event]
            while True:
                with self._hub:
                    while not ev.is_set() and not self.done.is_set():
                        self._hub.wait()
                    if self.done.is_set():
                        return
                    ev.clear()
                try:
                    fn()
                except Exception as e:                 # noqa: BLE001
                    self.log(f"responder {fn.__name__} crashed: {e!r}")
                    self.done.set()
        return run_responder
