"""SynApp: the paper's synthetic application for overhead measurement
(§IV-D1).  A Thinker + N workers; T identical tasks with duration D,
unique (non-cacheable) input of size I bytes and output of size O bytes.
The Thinker submits one task per worker, then one new task per completed
result, until T tasks are done -- measuring the full task lifecycle for
each {T, D, I, O, N} configuration (Figs. 5, 6, 9).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core import (ColmenaQueues, ProcessPoolTaskServer,
                        ShardedValueServer, TaskServer, ValueServer)
from repro.core.thinker import BaseThinker, agent, result_processor


@dataclass
class SynConfig:
    T: int = 200                 # total tasks
    D: float = 0.0               # task duration (s)
    I: int = 1 << 20             # input bytes
    O: int = 0                   # output bytes
    N: int = 8                   # workers
    use_value_server: bool = True
    proxy_threshold: int = 1 << 14
    seed: int = 0
    backend: str = "local"       # "local": thread workers, in-process queues;
                                 # "proc": broker-backed queues + N worker OS
                                 # processes + sharded socket Value Server
                                 # (the paper's multi-process topology)
    vs_shards: int = 2           # Value Server shards on the proc backend


class SynThinker(BaseThinker):
    def __init__(self, queues, cfg: SynConfig):
        super().__init__(queues)
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.results = []
        self.submitted = 0

    def _payload(self):
        # unique (non-cacheable) input
        return self.rng.integers(0, 255, size=self.cfg.I,
                                 dtype=np.uint8).tobytes()

    def _submit(self):
        self.queues.send_task(self._payload(), self.cfg.D, self.cfg.O,
                              method="syntask", topic="syntask")
        self.submitted += 1

    @agent
    def planner(self):
        for _ in range(min(self.cfg.N, self.cfg.T)):
            self._submit()

    @result_processor(topic="syntask")
    def consumer(self, result):
        assert result.success, result.error
        self.results.append(result)
        if len(self.results) >= self.cfg.T:
            self.done.set()
        elif self.submitted < self.cfg.T:
            self._submit()


def syntask(payload: bytes, duration: float, out_bytes: int) -> bytes:
    if duration:
        time.sleep(duration)
    return b"\0" * out_bytes


def run_synapp(cfg: SynConfig):
    """Returns per-component median lifecycle times + utilization."""
    proc = cfg.backend == "proc"
    if not cfg.use_value_server:
        vs = None
    elif proc:
        vs = ShardedValueServer(cfg.vs_shards)
    else:
        vs = ValueServer()
    queues = ColmenaQueues(
        ["syntask"], backend=cfg.backend, value_server=vs,
        proxy_threshold=cfg.proxy_threshold if cfg.use_value_server
        else None)
    if proc:
        server = ProcessPoolTaskServer(queues, workers_per_topic=cfg.N)
    else:
        server = TaskServer(queues, workers_per_topic=cfg.N)
    server.register(syntask, topic="syntask")
    thinker = SynThinker(queues, cfg)
    t0 = time.perf_counter()
    try:
        with server:
            thinker.run(timeout=600)
        makespan = time.perf_counter() - t0
    finally:
        queues.shutdown()
        if vs is not None and hasattr(vs, "shutdown"):
            vs.shutdown()

    comps = {}
    for r in thinker.results:
        for k, v in r.timer.intervals.items():
            comps.setdefault(k, []).append(v)
    medians = {k: float(np.median(v)) for k, v in comps.items()}
    busy = sum(r.task_runtime for r in thinker.results)
    overhead = {k: v for k, v in medians.items() if k != "execute"}
    n = len(thinker.results)
    return {
        "config": cfg.__dict__,
        "medians": medians,
        "total_overhead_median": float(sum(overhead.values())),
        "makespan": makespan,
        # end-to-end wall time amortized per task: at D=0 this exposes any
        # dispatch-latency floor the lifecycle medians could hide
        "per_task_wall": makespan / n if n else float("inf"),
        "utilization": busy / (cfg.N * makespan) if makespan else 0.0,
        "n_results": n,
    }
