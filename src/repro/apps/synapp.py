"""SynApp: the paper's synthetic application for overhead measurement
(§IV-D1).  A Thinker + N workers; T identical tasks with duration D,
unique (non-cacheable) input of size I bytes and output of size O bytes.
The Thinker submits one task per worker, then one new task per completed
result, until T tasks are done -- measuring the full task lifecycle for
each {T, D, I, O, N} configuration (Figs. 5, 6, 9).

SynApp doubles as the checkpoint/resume demo: with
``checkpoint_every=K`` the Thinker writes a fabric checkpoint (queued +
in-flight envelopes, claim window, Value Server contents, Thinker
progress, the full config) every K results, and
``run_synapp(cfg, resume_from=path)`` continues a ``kill -9``'d run from
the last checkpoint without resubmitting completed work.  The Value
Server may stay enabled: its snapshot travels inside the checkpoint, so
restored task/result proxies resolve in the new incarnation.  The same
works at cluster scale -- the transport snapshot becomes a federation
bundle and the VS snapshot spans the shard ring::

    PYTHONPATH=src python -m repro.apps.synapp --backend proc -T 200 \
        -D 0.05 --checkpoint-every 25 --ckpt /tmp/syn.ckpt
    PYTHONPATH=src python -m repro.apps.synapp --cluster 2 -T 200 \
        -D 0.05 --vs-replicas 2 --checkpoint-every 25 --ckpt /tmp/syn.ckpt
    # kill -9 either mid-run, then:
    PYTHONPATH=src python -m repro.apps.synapp --resume /tmp/syn.ckpt
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from dataclasses import dataclass

import numpy as np

from repro import observability as obs
from repro.core import (ColmenaQueues, ProcessPoolTaskServer,
                        ShardedValueServer, TaskServer, ValueServer,
                        streaming)
from repro.core.thinker import BaseThinker, agent, result_processor


@dataclass
class SynConfig:
    T: int = 200                 # total tasks
    D: float = 0.0               # task duration (s)
    I: int = 1 << 20             # input bytes
    O: int = 0                   # output bytes
    N: int = 8                   # workers
    use_value_server: bool = True
    proxy_threshold: int = 1 << 14
    seed: int = 0
    backend: str = "local"       # "local": thread workers, in-process queues;
                                 # "proc": broker-backed queues + N worker OS
                                 # processes + sharded socket Value Server
                                 # (the paper's multi-process topology)
    vs_shards: int = 2           # Value Server shards on the proc backend
    vs_replicas: int = 1         # copies of every VS key on the shard ring
                                 # (>=2 survives a shard/node loss)
    cluster_hosts: int = 0       # >=2: the multi-host topology -- that many
                                 # simulated hosts over TCP, each a federated
                                 # broker + worker pool (workers split across
                                 # hosts), Thinker attached to host 0
    cluster_thinker_remote: bool = False
                                 # all pools on hosts != the thinker's, so
                                 # every task crosses the federation relay
                                 # (the bench's relay-cost configuration)
    checkpoint_every: int = 0    # write a checkpoint every K results (0: off)
    checkpoint_path: str = ""    # where checkpoints go (required if K > 0)
    lease_timeout: float = 10.0  # unacked-delivery expiry; bounds how long a
                                 # resumed run waits to re-run in-flight work
    score_candidates: int = 0    # >0: Colmena-style steering -- the proxy
                                 # model (served by an inference shard) ranks
                                 # this many candidate inputs per submission
                                 # and the Thinker submits the best one
    inference_shards: int = 1    # scorer shard processes (proc/cluster
                                 # backends; the local backend serves the
                                 # proxy model from an in-process thread)
    trace_sample: float = 0.0    # >0: distributed tracing, sampling this
                                 # fraction of tasks (1.0 traces them all)
    trace_dir: str = ""          # span sink directory (default: a fresh
                                 # temp dir; feed it to
                                 # ``repro.observability.report``)
    cull_losers: float = 0.0     # >0: streaming steering -- tasks publish
                                 # partial results mid-run and the Thinker
                                 # preempts (broker-side cancel) the bottom
                                 # ``cull_losers`` fraction on their first
                                 # partial, resubmitting into the freed slot
    cull_steps: int = 4          # partials per task when culling: the task
                                 # duration is spent in this many slices
                                 # with report_intermediate between them


def proxy_scorer_factory():
    """The synapp "proxy model": a numpy LCG that maps a token prompt to
    a deterministic pseudo-score stream.  It exercises the full serving
    path -- bucketing, micro-batching, continuous decode, put-claim
    results -- without importing jax, so the steering demo runs on any
    backend at test speed.  Swap in
    ``repro.serving.shard.default_engine_factory`` for the real reduced
    model."""

    class _State:
        def __init__(self, cur, padded_b):
            self.cur = cur
            self.padded_b = padded_b

    class _ProxyModel:
        def prefill_batch(self, tokens, *, reserve=None, frames=None):
            first = (tokens.astype(np.int64).sum(axis=1) * 31 + 7) % 997
            return first, _State(first, tokens.shape[0])

        def decode_batch(self, state):
            state.cur = (state.cur * 31 + 7) % 997
            return state.cur

        def gather_rows(self, state, rows):
            idx = np.asarray(list(rows))
            return _State(state.cur[idx], len(idx))

    return _ProxyModel()


def _serve_spec(cfg: SynConfig):
    from repro.serving.shard import ServeSpec
    return ServeSpec(engine_factory=proxy_scorer_factory,
                     max_batch=max(cfg.score_candidates, 4),
                     max_batch_delay_ms=5.0)


class SynThinker(BaseThinker):
    def __init__(self, queues, cfg: SynConfig, *, submitted: int = 0,
                 completed: int = 0, scorer=None):
        """submitted/completed seed the progress counters when resuming
        from a checkpoint: already-completed work is never resubmitted,
        and the restored in-flight tasks drive the submit-per-completion
        loop forward.  scorer: an ``InferenceClient`` on the fabric's
        scorer shard; each submission then ranks
        ``cfg.score_candidates`` candidate inputs through it and submits
        the best-scored one (the paper's ML-in-the-loop steering)."""
        super().__init__(queues)
        self.cfg = cfg
        self.scorer = scorer
        self.scored = 0
        self.results = []
        self.submitted = submitted
        self.completed = completed
        # serializes submissions against checkpoints: a snapshot taken
        # between a submission being counted and its envelope landing
        # would record a task the restored queues don't contain
        self._sub_lock = threading.Lock()
        self._ckpt_due = False

    def _payload(self, idx: int, cand: int = 0):
        # unique (non-cacheable) input, keyed by submission index so a
        # resumed run continues the stream instead of replaying payloads
        # the original incarnation already sent
        rng = np.random.default_rng((self.cfg.seed, idx, cand))
        return rng.integers(0, 255, size=self.cfg.I,
                            dtype=np.uint8).tobytes()

    def _choose(self, idx: int) -> bytes:
        """Steered submission: score ``score_candidates`` candidate
        inputs through the proxy-model shard (one request per candidate;
        the shard micro-batches them) and return the best one."""
        k = self.cfg.score_candidates
        if self.scorer is None or k <= 1:
            return self._payload(idx)
        cands = [self._payload(idx, c) for c in range(k)]
        prompts = [list(c[:16]) for c in cands]
        results = self.scorer.infer(prompts, max_new=4, timeout=60.0)
        scores = [r.value[-1] if r.success else -1 for r in results]
        self.scored += k
        return cands[int(np.argmax(scores))]

    def _submit(self) -> bool:
        with self._sub_lock:
            if self.submitted >= self.cfg.T:
                return False
            idx = self.submitted
            self.submitted += 1
            # send inside the lock: count and envelope move together
            # relative to any concurrent checkpoint.  Scoring sits
            # inside too -- the candidates' infer round trip must not
            # race a checkpoint either, or the snapshot could capture
            # the scorer requests without the submission they feed
            self.queues.send_task(self._choose(idx), self.cfg.D,
                                  self.cfg.O, self.cfg.cull_steps
                                  if self.cfg.cull_losers else 0,
                                  method="syntask", topic="syntask")
        return True

    def _checkpoint(self):
        with self._sub_lock:
            self.queues.checkpoint(
                self.cfg.checkpoint_path,
                extra={"submitted": self.submitted,
                       "completed": self.completed,
                       "T": self.cfg.T, "cfg": dict(self.cfg.__dict__)})

    @agent
    def planner(self):
        # top up to N in flight (on a fresh run: submit N; on resume the
        # restored in-flight tasks already count toward the window)
        while (self.submitted - self.completed < self.cfg.N
               and self._submit()):
            pass
        if self.completed >= self.cfg.T:    # resumed post-completion
            self.done.set()

    @result_processor(topic="syntask")
    def consumer(self, result):
        assert result.success, result.error
        self.results.append(result)
        self._advance()

    def _advance(self):
        """Count one campaign outcome -- a delivered result, or (in the
        culling subclass) a preemption decision -- and keep the
        submit-per-outcome loop moving.  The count mutates under
        ``_sub_lock``: the consumer thread and the stream-drain threads
        both land here."""
        with self._sub_lock:
            self.completed += 1
            completed = self.completed
        if (self.cfg.checkpoint_every
                and completed % self.cfg.checkpoint_every == 0):
            # defer to the batch boundary: mid-batch, sibling results of
            # this drain are decoded (acked out of the broker) but not
            # yet counted -- a snapshot here would lose them on resume
            self._ckpt_due = True
        if completed >= self.cfg.T:
            # done.set() suppresses the batch-boundary hook, so flush a
            # pending checkpoint here -- at T every delivered result is
            # counted, which is exactly the boundary the hook waits for
            if self._ckpt_due:
                self._ckpt_due = False
                self._checkpoint()
            self.done.set()
        else:
            self._submit()

    def after_result_batch(self, topic):
        if self._ckpt_due:
            self._ckpt_due = False
            self._checkpoint()


class CullingSynThinker(SynThinker):
    """Streaming steering (``cull_losers``): syntask spends its duration
    in ``cull_steps`` slices, publishing a partial after each; this
    Thinker reads the first partial's pseudo-score and preempts the
    bottom ``cull_losers`` fraction via broker-side ``cancel`` -- the
    loser stops burning its worker after one slice instead of running to
    completion, and the freed slot is resubmitted immediately.  A cull
    counts as a campaign outcome (the steering policy *decided* that
    task), so T outcomes still terminate the run."""

    def __init__(self, queues, cfg: SynConfig, **kw):
        super().__init__(queues, cfg, **kw)
        self.culled = 0
        self._decided: set = set()

    def process_intermediate(self, ob):
        if ob.value["score"] >= self.cfg.cull_losers:
            return                      # keeper: let it run out
        if ob.task_id in self._decided:
            return                      # later slices of a known loser
        self._decided.add(ob.task_id)
        if self.queues.cancel(ob.task_id, "syntask"):
            # won the cancel-vs-completion race: the task will never
            # deliver a result, so the cull itself is the outcome
            with self._sub_lock:
                self.culled += 1
            self._advance()
        # lost the race: the completion is already enqueued and the
        # consumer counts it -- nothing to do here


def syntask(payload: bytes, duration: float, out_bytes: int,
            steps: int = 0) -> bytes:
    """steps=0: the paper's opaque synthetic task (sleep D, emit O
    bytes).  steps>0: the streaming variant -- the duration is spent in
    that many slices with a partial published after each, carrying a
    pseudo-score derived from the payload (deterministic, so local and
    pool workers rank identically).  ``report_intermediate`` raises
    ``TaskCancelled`` between slices once the Thinker culls this task."""
    if steps:
        score = int.from_bytes(payload[:8].ljust(8, b"\0"),
                               "little") / 2 ** 64
        dt = duration / steps
        for i in range(steps):
            if dt:
                time.sleep(dt)
            streaming.report_intermediate({"step": i, "score": score})
        return b"\0" * out_bytes
    if duration:
        time.sleep(duration)
    return b"\0" * out_bytes


def _cluster_spec(cfg: SynConfig):
    """The synapp cluster topology: ``cluster_hosts`` simulated hosts,
    each a federated broker, with the N workers split across the pool
    hosts.  Default: every host pools syntask and the Thinker sits with
    host 0 (its topic traffic is broker-local; other hosts relay).
    ``cluster_thinker_remote``: host 0 runs *no* pool, so every task
    submission and result crosses exactly one relay hop -- the
    configuration the relay-cost bench row measures."""
    from repro.core.cluster import ClusterSpec, HostSpec
    k = cfg.cluster_hosts
    pool_hosts = list(range(1, k)) if cfg.cluster_thinker_remote \
        else list(range(k))
    share, rem = divmod(cfg.N, len(pool_hosts))
    workers = {h: share + (1 if i < rem else 0)
               for i, h in enumerate(pool_hosts)}
    shards = {}
    if cfg.use_value_server:
        for i in range(cfg.vs_shards):
            h = pool_hosts[i % len(pool_hosts)]
            shards[h] = shards.get(h, 0) + 1
    infer = cfg.inference_shards if cfg.score_candidates else 0
    hosts = [HostSpec(f"h{i}", thinker=(i == 0),
                      pools=({"syntask": workers[i]} if workers.get(i)
                             else {}),
                      vs_shards=shards.get(i, 0),
                      # scorer shards sit with the Thinker's host so the
                      # steering round trip stays broker-local
                      inference_shards=(infer if i == 0 else 0))
             for i in range(k)]
    return ClusterSpec(hosts, lease_timeout=cfg.lease_timeout,
                       vs_replicas=(cfg.vs_replicas if cfg.use_value_server
                                    else 1))


def _run_cluster(cfg: SynConfig, progress, resume_from: str = "",
                 ckpt_payload=None):
    """Materialize the spec, attach the Thinker to its host's broker,
    and run the campaign across the simulated hosts.  ``resume_from``
    restores the federation bundle + Value Server snapshot into the
    fresh cluster before the Thinker starts submitting (host names are
    derived from the config, so the restored per-member cuts land on
    their namesakes)."""
    from repro.core.cluster import ClusterLauncher
    threshold = cfg.proxy_threshold if cfg.use_value_server else None
    serve = _serve_spec(cfg) if cfg.score_candidates else None
    launcher = ClusterLauncher(
        _cluster_spec(cfg),
        methods=[(syntask, {"topic": "syntask"})],
        proxy_threshold=threshold, serve_spec=serve)
    t0 = time.perf_counter()
    with launcher:
        vs = launcher.value_server() if cfg.use_value_server else None
        queues = launcher.connect(["syntask"], value_server=vs,
                                  proxy_threshold=threshold,
                                  serve_spec=serve)
        scorer = None
        if serve is not None:
            from repro.serving.shard import InferenceClient
            scorer = InferenceClient(queues)
        try:
            if resume_from:
                progress = queues.resume(resume_from, payload=ckpt_payload)
                cfg.T = progress.get("T", cfg.T)
            cls = CullingSynThinker if cfg.cull_losers else SynThinker
            thinker = cls(queues, cfg,
                          submitted=progress["submitted"],
                          completed=progress["completed"],
                          scorer=scorer)
            thinker.run(timeout=600)
            makespan = time.perf_counter() - t0
        finally:
            queues.shutdown()
            queues.transport.client.close()
    return thinker, makespan


def run_synapp(cfg: SynConfig, resume_from: str = ""):
    """Returns per-component median lifecycle times + utilization.
    ``resume_from``: continue from a checkpoint file instead of starting
    fresh (the fabric state is restored *before* workers start)."""
    ckpt_payload = None
    if resume_from:
        # the campaign's config travels with the checkpoint: a resume
        # continues *that* run (same durations, sizes, backend, paths),
        # so peek at it before building the fabric it configures (one
        # read -- the payload is handed to resume() below)
        ckpt_payload = ColmenaQueues.load_checkpoint(resume_from)
        for k, v in (ckpt_payload["extra"] or {}).get("cfg", {}).items():
            setattr(cfg, k, v)
    if cfg.checkpoint_every and not cfg.checkpoint_path:
        raise ValueError("checkpoint_every is set but checkpoint_path is "
                         "empty -- the first checkpoint would fail inside "
                         "the consumer thread and hang the run")
    if cfg.trace_sample:
        # export before any fabric process exists: forked brokers,
        # shards and agents inherit the sink config (the cluster path
        # additionally stamps per-host identity into agent/shard env)
        cfg.trace_dir = (cfg.trace_dir or os.environ.get(obs.ENV_DIR)
                         or tempfile.mkdtemp(prefix="repro-obs-"))
        os.environ[obs.ENV_DIR] = cfg.trace_dir
        os.environ[obs.ENV_SAMPLE] = repr(cfg.trace_sample)
    if cfg.cluster_hosts:
        if cfg.cluster_hosts < 2:
            raise ValueError("cluster_hosts simulates a multi-host fabric:"
                             " use >= 2 (or 0 for single-host backends)")
        thinker, makespan = _run_cluster(
            cfg, {"submitted": 0, "completed": 0},
            resume_from=resume_from, ckpt_payload=ckpt_payload)
        return _metrics(cfg, thinker, makespan)
    proc = cfg.backend == "proc"
    if not cfg.use_value_server:
        vs = None
    elif proc:
        if cfg.vs_replicas > cfg.vs_shards:
            # same contract as ClusterSpec: an unsatisfiable replica
            # factor is a misconfiguration, not a silent downgrade
            raise ValueError(
                f"vs_replicas={cfg.vs_replicas} exceeds vs_shards="
                f"{cfg.vs_shards}: the replica factor cannot be satisfied")
        vs = ShardedValueServer(cfg.vs_shards, replicas=cfg.vs_replicas)
    else:
        vs = ValueServer()
    serve = _serve_spec(cfg) if cfg.score_candidates else None
    queues = ColmenaQueues(
        ["syntask"], backend=cfg.backend, value_server=vs,
        proxy_threshold=cfg.proxy_threshold if cfg.use_value_server
        else None, lease_timeout=cfg.lease_timeout, serve_spec=serve)
    scorer = None
    shard_procs: list = []
    serve_thread = None
    if serve is not None:
        from repro.serving.shard import (InferenceClient, ServeLoop,
                                         start_inference_shard)
        scorer = InferenceClient(queues)
        if proc:
            shard_procs = [
                start_inference_shard(queues.transport.address, serve,
                                      lease_timeout=cfg.lease_timeout,
                                      identity=f"infer@proc:{i}")
                for i in range(max(cfg.inference_shards, 1))]
        else:
            # local backend: no process to fork -- serve the proxy model
            # from a thread over the same in-process transport
            loop = ServeLoop(queues.transport, serve,
                             identity="infer@local:0")
            serve_thread = threading.Thread(target=loop.run, daemon=True,
                                            name="synapp-scorer")
            serve_thread.start()
    progress = {"submitted": 0, "completed": 0}
    if resume_from:
        progress = queues.resume(resume_from, payload=ckpt_payload)
        cfg.T = progress.get("T", cfg.T)    # totals travel with the ckpt
    if proc:
        server = ProcessPoolTaskServer(queues, workers_per_topic=cfg.N)
    else:
        server = TaskServer(queues, workers_per_topic=cfg.N)
    server.register(syntask, topic="syntask")
    cls = CullingSynThinker if cfg.cull_losers else SynThinker
    thinker = cls(queues, cfg, submitted=progress["submitted"],
                  completed=progress["completed"], scorer=scorer)
    t0 = time.perf_counter()
    try:
        with server:
            thinker.run(timeout=600)
        makespan = time.perf_counter() - t0
    finally:
        if serve is not None:
            # graceful: one stop marker per consumer of the serve topic
            from repro.serving.shard import send_shard_stop
            try:
                send_shard_stop(queues.transport, serve.topic,
                                n=len(shard_procs) or 1)
            except (ConnectionError, OSError):
                pass
            if serve_thread is not None:
                serve_thread.join(timeout=5)
            for p in shard_procs:
                p.join(timeout=5)
                if p.is_alive():
                    p.terminate()
        queues.shutdown()
        if vs is not None and hasattr(vs, "shutdown"):
            vs.shutdown()
    return _metrics(cfg, thinker, makespan)


def _metrics(cfg: SynConfig, thinker: SynThinker, makespan: float):
    comps = {}
    for r in thinker.results:
        for k, v in r.timer.intervals.items():
            comps.setdefault(k, []).append(v)
    medians = {k: float(np.median(v)) for k, v in comps.items()}
    busy = sum(r.task_runtime for r in thinker.results)
    overhead = {k: v for k, v in medians.items() if k != "execute"}
    n = len(thinker.results)
    return {
        "config": cfg.__dict__,
        "medians": medians,
        "total_overhead_median": float(sum(overhead.values())),
        "makespan": makespan,
        # end-to-end wall time amortized per task: at D=0 this exposes any
        # dispatch-latency floor the lifecycle medians could hide
        "per_task_wall": makespan / n if n else float("inf"),
        "utilization": busy / (cfg.N * makespan) if makespan else 0.0,
        "n_results": n,
        "completed_total": thinker.completed,
        # steering: candidate inputs ranked through the scorer shard
        "scored": thinker.scored,
        # streaming steering: tasks preempted on their first partial
        "culled": getattr(thinker, "culled", 0),
        # cluster runs: which hosts actually executed work (from the
        # winning worker identities)
        "hosts_seen": sorted({r.worker.split("/", 1)[0]
                              for r in thinker.results if r.worker}),
        # where the span/metric sinks landed (empty when untraced):
        # ``python -m repro.observability.report <dir>`` renders them
        "trace_dir": cfg.trace_dir if cfg.trace_sample else "",
    }


def main(argv=None):
    import argparse
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("-T", type=int, default=200, help="total tasks")
    p.add_argument("-D", type=float, default=0.0, help="task duration (s)")
    p.add_argument("-I", type=int, default=1 << 20, help="input bytes")
    p.add_argument("-N", type=int, default=8, help="workers")
    p.add_argument("--backend", choices=("local", "proc"), default="local")
    p.add_argument("--cluster", type=int, default=0, metavar="K",
                   help="run on K simulated hosts over TCP (federated "
                        "brokers + per-host worker pools; implies the "
                        "proc-style topology)")
    p.add_argument("--no-value-server", action="store_true")
    p.add_argument("--vs-replicas", type=int, default=1, metavar="R",
                   help="Value Server replica factor (>=2 keeps keys "
                        "readable through a shard/node loss)")
    p.add_argument("--score-candidates", type=int, default=0, metavar="C",
                   help="rank C candidate inputs per task through the "
                        "proxy-model inference shard and submit the best "
                        "(ML-in-the-loop steering)")
    p.add_argument("--inference-shards", type=int, default=1,
                   help="scorer shard processes (proc/cluster backends)")
    p.add_argument("--checkpoint-every", type=int, default=0, metavar="K",
                   help="checkpoint the fabric every K results")
    p.add_argument("--ckpt", default="synapp.ckpt",
                   help="checkpoint file path")
    p.add_argument("--resume", default="",
                   help="resume from this checkpoint file")
    p.add_argument("--cull-losers", type=float, default=0.0, metavar="F",
                   help="streaming steering: tasks publish partials and "
                        "the bottom F fraction (by first-partial score) "
                        "is preempted mid-run, freeing its worker slot")
    p.add_argument("--cull-steps", type=int, default=4, metavar="S",
                   help="partials per task when culling (the duration is "
                        "spent in S slices)")
    p.add_argument("--trace", nargs="?", const=1.0, type=float,
                   default=0.0, metavar="RATE",
                   help="distributed tracing: sample RATE of tasks "
                        "(bare --trace samples all of them)")
    p.add_argument("--trace-dir", default="", metavar="DIR",
                   help="span sink directory (default: a fresh temp dir, "
                        "printed at the end)")
    args = p.parse_args(argv)
    cfg = SynConfig(T=args.T, D=args.D, I=args.I, N=args.N,
                    backend=args.backend, cluster_hosts=args.cluster,
                    use_value_server=not args.no_value_server,
                    vs_replicas=args.vs_replicas,
                    score_candidates=args.score_candidates,
                    inference_shards=args.inference_shards,
                    checkpoint_every=args.checkpoint_every,
                    checkpoint_path=args.ckpt,
                    cull_losers=args.cull_losers, cull_steps=args.cull_steps,
                    trace_sample=args.trace, trace_dir=args.trace_dir)
    res = run_synapp(cfg, resume_from=args.resume)
    hosts = (f"  hosts {','.join(res['hosts_seen'])}"
             if args.cluster else "")
    scored = f"  scored {res['scored']}" if res["scored"] else ""
    scored += f"  culled {res['culled']}" if res["culled"] else ""
    print(f"completed {res['completed_total']}/{cfg.T} "
          f"({res['n_results']} this run)  "
          f"makespan {res['makespan']:.2f}s  "
          f"per-task wall {res['per_task_wall']*1e3:.2f}ms  "
          f"median overhead {res['total_overhead_median']*1e3:.2f}ms"
          f"{hosts}{scored}")
    if res["trace_dir"]:
        print(f"trace sinks: {res['trace_dir']}  (render: "
              f"python -m repro.observability.report {res['trace_dir']})")
    return res


if __name__ == "__main__":
    main()
