"""The paper's molecular-design application (§II-B, §IV, Fig. 2).

An ML-guided search over a fixed molecule space for high ionization
potential: a UCB-ranked molecule queue steers expensive "QC" assays
(synthetic spectral oracle -- see data/molecules.py for the simulated
gate), an MPNN ensemble (JAX) provides the cheap learned assay, and the
Thinker's agent pairs mirror Fig. 2:

    QC-Scorer / QC-Recorder    pull from the queue; record results
    Trainer  / Updater         retrain the ensemble every n_retrain results
    ML-Scorer / ML-Recorder    re-score + reorder the queue on model update
    Allocator                  moves worker slots between qc/ml pools

Three policies reproduce Fig. 4: "random", "no-retrain", "update-n".
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import mpnn_surrogate
from repro.core import (CampaignRecord, ColmenaQueues, Observation,
                        ResourceTracker, TaskServer, ValueServer)
from repro.core.thinker import BaseThinker, agent, result_processor
from repro.data import molecules
from repro.models import mpnn


@dataclass
class AppConfig:
    num_molecules: int = 800
    initial_train: int = 48          # pre-campaign QC data (paper: 2563)
    qc_budget: int = 120             # QC assays during the campaign
    parallel_qc: int = 4
    n_retrain: int = 16              # paper's update-8, scaled
    policy: str = "update-n"         # random | no-retrain | update-n
    ucb_kappa: float = 2.0
    train_epochs: int = 200
    lr: float = 5e-3
    qc_cost: float = 6.0             # node-hours per assay (paper's number)
    seed: int = 0
    # "high-performing" threshold; 11.0 V puts ~0.3% of the synthetic space
    # above it, matching the paper's 0.5% random-success baseline
    high_ip: float = 11.0


# ---------------------------------------------------------------------------
# Learned assay: MPNN ensemble train + predict (jitted)
# ---------------------------------------------------------------------------


class Surrogate:
    """MPNN ensemble with standardized targets, trained with Adam; each
    member sees a different bootstrap subsample (the paper's recipe for
    getting an uncertainty estimate out of the ensemble)."""

    def __init__(self, cfg: mpnn_surrogate.MPNNConfig, seed: int = 0):
        self.cfg = cfg
        keys = jax.random.split(jax.random.PRNGKey(seed), cfg.ensemble)
        self.params = jax.vmap(lambda k: _init_one(cfg, k))(keys)
        self.y_mean, self.y_std = 0.0, 1.0
        self._predict = jax.jit(
            lambda p, a, b, m: mpnn.ensemble_apply(p, a, b, m, cfg))
        self._train = jax.jit(self._train_impl, static_argnums=(3,))

    def _train_impl(self, stacked_params, batch, lr, epochs):
        b1, b2, eps = 0.9, 0.999, 1e-8

        def one_member(params, key):
            n = batch["y"].shape[0]
            # bootstrap subsample per member (paper: different subsets)
            idx = jax.random.randint(key, (n,), 0, n)
            sub = jax.tree.map(lambda t: t[idx], batch)
            zeros = jax.tree.map(jnp.zeros_like, params)

            def epoch(carry, t):
                p, m, v = carry
                loss, g = jax.value_and_grad(mpnn.mpnn_loss)(p, sub, self.cfg)
                m = jax.tree.map(lambda a, b_: b1 * a + (1 - b1) * b_, m, g)
                v = jax.tree.map(lambda a, b_: b2 * a + (1 - b2) * b_ ** 2,
                                 v, g)
                c1 = 1 - b1 ** (t + 1.0)
                c2 = 1 - b2 ** (t + 1.0)
                p = jax.tree.map(
                    lambda w, mm, vv: w - lr * (mm / c1)
                    / (jnp.sqrt(vv / c2) + eps), p, m, v)
                return (p, m, v), loss

            (params, _, _), losses = jax.lax.scan(
                epoch, (params, zeros, zeros),
                jnp.arange(epochs, dtype=jnp.float32))
            return params, losses[-1]

        keys = jax.random.split(jax.random.PRNGKey(1), self.cfg.ensemble)
        return jax.vmap(one_member)(stacked_params, keys)

    def train(self, feats, y, lr, epochs):
        y = np.asarray(y, np.float64)
        self.y_mean = float(y.mean())
        self.y_std = float(max(y.std(), 1e-3))
        y_n = (y - self.y_mean) / self.y_std
        batch = {**feats, "y": jnp.asarray(y_n, jnp.float32)}
        self.params, losses = self._train(self.params, batch,
                                          jnp.asarray(lr), epochs)
        return float(jnp.mean(losses))

    def predict(self, feats) -> np.ndarray:
        preds = self._predict(self.params, feats["atoms"], feats["bonds"],
                              feats["mask"])
        return np.asarray(preds) * self.y_std + self.y_mean   # (E, B)

    def mae(self, feats, y) -> float:
        return float(np.mean(np.abs(self.predict(feats).mean(0) - y)))


def _init_one(cfg, key):
    from repro.models.layers import InitMaker
    return mpnn.mpnn_params(InitMaker(key, jnp.float32), cfg)


# ---------------------------------------------------------------------------
# The Thinker (Fig. 2)
# ---------------------------------------------------------------------------


class MoleculeThinker(BaseThinker):
    def __init__(self, queues, app: AppConfig, space, surrogate, record,
                 resources):
        super().__init__(queues, resources)
        self.app = app
        self.space = space
        self.surrogate = surrogate
        self.record = record
        self.rng = np.random.default_rng(app.seed)
        self.lock = threading.Lock()
        self.queue_order = list(range(app.num_molecules))  # molecule queue
        self.in_flight: set = set()
        self.evaluated: set = set()
        self.since_retrain = 0
        self.retraining = False
        self.t0 = time.perf_counter()
        self.trace: list = []                 # (t, event, payload)
        self.all_feats = molecules.featurize(space, range(app.num_molecules))
        self.all_feats = jax.tree.map(jnp.asarray, self.all_feats)

    # -- helpers ---------------------------------------------------------------

    def _t(self):
        return time.perf_counter() - self.t0

    def _next_molecule(self):
        with self.lock:
            for m in self.queue_order:
                if m not in self.evaluated and m not in self.in_flight:
                    self.in_flight.add(m)
                    return m
        return None

    def _reorder(self):
        """ML-Recorder: recompute UCB over the whole space, reorder queue."""
        preds = self.surrogate.predict(self.all_feats)          # (E, N)
        from repro.core.policies import ucb_scores
        scores = ucb_scores(preds, self.app.ucb_kappa)
        with self.lock:
            self.queue_order = list(np.argsort(-scores))
        self.trace.append((self._t(), "reorder", None))

    # -- agents -----------------------------------------------------------------

    @agent
    def qc_scorer(self):
        if self.app.policy == "random":
            with self.lock:
                self.rng.shuffle(self.queue_order)
        else:
            self._reorder()                   # initial (pretrained) ranking
        for _ in range(self.app.parallel_qc):
            self._submit_next()

    def _submit_next(self):
        m = self._next_molecule()
        if m is not None:
            self.queues.send_task(int(m), method="qc", topic="qc")

    @result_processor(topic="qc")
    def qc_recorder(self, result):
        assert result.success, result.error
        m, value = result.args[0], result.value
        with self.lock:
            self.in_flight.discard(m)
            self.evaluated.add(m)
        self.record.add(Observation(str(m), "qc", "ip", float(value),
                                    cost=self.app.qc_cost, time=self._t()))
        self.trace.append((self._t(), "qc", (m, float(value))))
        n = self.record.count("qc")
        if n >= self.app.qc_budget:
            self.done.set()
            return
        self.since_retrain += 1
        if (self.app.policy == "update-n"
                and self.since_retrain >= self.app.n_retrain
                and not self.retraining):
            self.since_retrain = 0
            self.retraining = True
            ids = [int(o.entity) for o in self.record.observations()
                   if o.assay == "qc"]
            ys = [o.value for o in self.record.observations()
                  if o.assay == "qc"]
            self.queues.send_task(ids, ys, method="retrain", topic="retrain")
        self._submit_next()

    @result_processor(topic="retrain")
    def updater(self, result):
        """Updater + ML-Scorer: install new weights, re-rank the queue."""
        assert result.success, result.error
        self.surrogate.params = result.value
        self.trace.append((self._t(), "retrain", None))
        self._reorder()
        self.retraining = False


# ---------------------------------------------------------------------------
# Campaign driver
# ---------------------------------------------------------------------------


def run_campaign(app: AppConfig, *, verbose: bool = False):
    space = molecules.MoleculeSpace(num_molecules=app.num_molecules,
                                    seed=42)
    cfg = mpnn_surrogate.reduced()
    surrogate = Surrogate(cfg, seed=app.seed)

    # pre-campaign training set (paper: initial ensemble trained on QC data)
    pre_ids = list(range(app.num_molecules))[: app.initial_train]
    pre_y = molecules.oracle_batch(space, pre_ids)
    pre_feats = jax.tree.map(jnp.asarray, molecules.featurize(space, pre_ids))
    if app.policy != "random":
        surrogate.train(pre_feats, pre_y, app.lr, app.train_epochs)
    init_mae_ids = list(range(app.num_molecules - 64, app.num_molecules))
    mae0 = surrogate.mae(
        jax.tree.map(jnp.asarray, molecules.featurize(space, init_mae_ids)),
        molecules.oracle_batch(space, init_mae_ids))

    record = CampaignRecord(lambda d: d.get("ip"))
    vs = ValueServer()
    queues = ColmenaQueues(["qc", "retrain"], value_server=vs,
                           proxy_threshold=1 << 16)
    resources = ResourceTracker({"qc": app.parallel_qc, "retrain": 1})
    server = TaskServer(queues, workers_per_topic=app.parallel_qc,
                        resources=resources)

    def qc(mol_id: int) -> float:
        return molecules.qc_oracle(space, mol_id)

    def retrain(ids, ys):
        feats = jax.tree.map(jnp.asarray, molecules.featurize(space, ids))
        y = np.concatenate([pre_y, np.asarray(ys)])
        f = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), pre_feats, feats)
        surrogate.train(f, y, app.lr, app.train_epochs)
        return surrogate.params

    server.register(qc, topic="qc", pool="qc")
    server.register(retrain, topic="retrain", pool="retrain")

    thinker = MoleculeThinker(queues, app, space, surrogate, record,
                              resources)
    with server:
        thinker.run(timeout=600)

    obs = [o for o in record.observations() if o.assay == "qc"]
    values = np.array([o.value for o in obs])
    times = np.array([o.time for o in obs])
    n_high = int(np.sum(values >= app.high_ip))
    out = {
        "policy": app.policy,
        "n_evaluated": len(values),
        "n_high": n_high,
        "success_rate": n_high / max(len(values), 1),
        "best": float(values.max()) if len(values) else None,
        "mean_last_quarter": float(values[-len(values) // 4:].mean())
        if len(values) >= 4 else None,
        "initial_mae": mae0,
        "final_mae": surrogate.mae(
            jax.tree.map(jnp.asarray,
                         molecules.featurize(space, init_mae_ids)),
            molecules.oracle_batch(space, init_mae_ids)),
        "cost": record.cost(),
        "V": record.value(),
        "times": times.tolist(),
        "values": values.tolist(),
        "trace": thinker.trace,
    }
    if verbose:
        print(f"[{app.policy}] evaluated={out['n_evaluated']} "
              f"high-IP(>= {app.high_ip}V)={out['n_high']} "
              f"success={out['success_rate']:.1%} best={out['best']:.2f}V "
              f"V(D)={out['V']:.2f} C(D)={out['cost']:.0f} node-h "
              f"mae {out['initial_mae']:.3f}->{out['final_mae']:.3f}")
    return out
