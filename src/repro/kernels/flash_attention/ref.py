"""Pure-jnp oracle for the flash-attention kernel: full-softmax attention
(materializes the score matrix; small shapes only)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_reference(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None, q_offset: int = 0):
    """q (B,Sq,H,hd); k/v (B,Sk,KVH,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    ke = jnp.repeat(k, G, axis=2) if G > 1 else k
    ve = jnp.repeat(v, G, axis=2) if G > 1 else v
    s = jnp.einsum("bihd,bjhd->bhij", q.astype(jnp.float32) * hd ** -0.5,
                   ke.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhij,bjhd->bihd", p, ve.astype(jnp.float32))
    return o.astype(q.dtype)
