"""TPU Pallas flash attention (blockwise online softmax).

Grid: (batch*q_heads, num_q_blocks, num_kv_blocks) -- the KV dimension is
minormost so each (bh, iq) pair iterates its KV blocks sequentially on a
TPU core while the online-softmax state (m, l, acc) lives in VMEM scratch.
GQA is handled in the k/v index maps (query head bh reads KV head bh // G),
so K/V are never physically repeated.  Causal masking, static sliding
windows and logit softcap are supported; fully-masked KV blocks are skipped
with pl.when (they still occupy grid slots -- the q-block-aligned variant
that trims them is a perf lever, not a semantics change).

Block shapes default to (128, head_dim) tiles: MXU-aligned on the matmul
dims and small enough that q/k/v blocks + f32 scratch fit VMEM
(3*128*hd*2B + 128*hd*4B + 128*128*4B ~ 360 KB at hd=128).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], q_offset: int, bq: int, bk: int,
            nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos0 = q_offset + iq * bq
    kpos0 = ik * bk
    # static-shape live test for this (iq, ik) pair:
    live = True
    if causal:
        live = jnp.asarray(kpos0 <= qpos0 + bq - 1)
    if window is not None:
        live = jnp.logical_and(
            live, qpos0 - (kpos0 + bk - 1) < window) if causal else \
            jnp.asarray(qpos0 - (kpos0 + bk - 1) < window)

    @pl.when(live if not isinstance(live, bool) else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)[:, None]              # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_scr[...] = l_prev * corr + jnp.sum(p, -1)[:, None]
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset",
                     "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q (B,Sq,H,hd); k/v (B,Sk,KVH,hd) -> (B,Sq,H,hd).

    interpret=True executes the kernel body in Python on CPU (the validation
    mode for this container); on a real TPU pass interpret=False.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    # (B,S,H,hd) -> (B*H, S, hd) rows; kv rows indexed by bh // G
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * KVH, Sk, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * KVH, Sk, hd)

    def kv_row(bh):
        return (bh // (H // KVH)) if G > 1 else bh

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, bq=bq, bk=bk, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(B, H, Sq, hd), 1, 2)
