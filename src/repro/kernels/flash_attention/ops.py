"""Jitted wrapper / dispatcher for flash attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import flash_attention  # noqa: F401

# interpret=True is the default inside flash_attention (CPU validation);
# a TPU deployment calls flash_attention(..., interpret=False).

attention_reference = ref.attention_reference


def attention(q, k, v, *, impl: str = "kernel", **kw):
    if impl == "kernel":
        return flash_attention(q, k, v, **kw)
    return ref.attention_reference(q, k, v, **{
        k_: v_ for k_, v_ in kw.items()
        if k_ in ("causal", "window", "softcap", "q_offset")})
