"""Pure-jnp oracles for the Mamba2 state-space-dual (SSD) scan.

Contract (shared by ref, naive and Pallas implementations):

    y, final_state = ssd(x, log_a, b, c, initial_state, chunk)

    x:      (B, L, H, P)   inputs, already scaled by dt
    log_a:  (B, L, H)      per-step log decay, log a_t <= 0
    b:      (B, L, G, N)   input projections  (G groups; H % G == 0)
    c:      (B, L, G, N)   output projections
    state:  (B, H, P, N)

    recurrence (per head h with group g = h * G // H):
        S_t = a_t * S_{t-1} + x_t (outer) b_t
        y_t = S_t @ c_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(t, H):
    """(B, L, G, N) -> (B, L, H, N) by repeating each group."""
    B, L, G, N = t.shape
    rep = H // G
    return jnp.repeat(t, rep, axis=2) if rep > 1 else t


def ssd_naive(x, log_a, b, c, initial_state=None):
    """Step-by-step scan; the ground-truth oracle for tests."""
    B, L, H, P = x.shape
    N = b.shape[-1]
    bf = _expand_groups(b.astype(jnp.float32), H)
    cf = _expand_groups(c.astype(jnp.float32), H)
    xf = x.astype(jnp.float32)
    af = jnp.exp(log_a.astype(jnp.float32))
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        xt, at, bt, ct = inp          # (B,H,P), (B,H), (B,H,N), (B,H,N)
        s = s * at[..., None, None] + xt[..., None] * bt[..., None, :]
        yt = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, yt

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    s, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)          # (B,L,H,P)
    return y, s


def _segsum(log_a):
    """(..., Q) -> (..., Q, Q) lower-triangular pairwise decay sums:
    out[i, j] = sum_{j < s <= i} log_a[s]  (i >= j), -inf above diagonal."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # cum[i] - cum[j]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, log_a, b, c, initial_state=None, chunk: int = 128,
                unroll: bool = False):
    """Chunked SSD: quadratic intra-chunk attention + inter-chunk recurrence.

    Identical numerics target as ssd_naive; O(L/Q) sequential steps.
    `unroll` unrolls the inter-chunk scan (dry-run cost probes).
    """
    B, L, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    xf = x.astype(jnp.float32).reshape(B, nc, Q, H, P)
    la = log_a.astype(jnp.float32).reshape(B, nc, Q, H)
    bf = _expand_groups(b.astype(jnp.float32), H).reshape(B, nc, Q, H, N)
    cf = _expand_groups(c.astype(jnp.float32), H).reshape(B, nc, Q, H, N)
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    # intra-chunk ("attention") term, computed in parallel over chunks
    la_t = jnp.moveaxis(la, -1, 2)                      # (B,nc,H,Q)
    Lmat = jnp.exp(_segsum(la_t))                       # (B,nc,H,Q,Q)
    scores = jnp.einsum("bnihs,bnjhs->bnhij", cf, bf)   # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bnhij,bnhij,bnjhp->bnihp",
                         scores, Lmat, xf)              # (B,nc,Q,H,P)

    # per-chunk aggregated state contribution and total decay
    cum = jnp.cumsum(la_t, axis=-1)                     # (B,nc,H,Q)
    total = cum[..., -1:]                               # (B,nc,H,1)
    decay_to_end = jnp.exp(total - cum)                 # (B,nc,H,Q)
    chunk_state = jnp.einsum("bnjhs,bnhj,bnjhp->bnhps",
                             bf, decay_to_end, xf)      # (B,nc,H,P,N)

    # inter-chunk recurrence over nc steps
    def step(s, inp):
        cs, tot = inp                                   # (B,H,P,N), (B,H,1)
        s_in = s
        s = s * jnp.exp(tot)[..., None] + cs
        return s, s_in

    xs = (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(total, 1, 0))
    s_final, s_prevs = jax.lax.scan(step, s0, xs, unroll=unroll)
    s_prev = jnp.moveaxis(s_prevs, 0, 1)                # (B,nc,H,P,N)

    # inter-chunk output: y_t += C_t . (decay_in(t) * S_prev)
    decay_in = jnp.exp(cum)                             # (B,nc,H,Q)
    y_inter = jnp.einsum("bnihs,bnhi,bnhps->bnihp", cf, decay_in, s_prev)

    y = (y_intra + y_inter).reshape(B, L, H, P).astype(x.dtype)
    return y, s_final


def ssd_step(x_t, log_a_t, b_t, c_t, state):
    """Single decode step. x_t (B,H,P); log_a_t (B,H); b/c (B,G,N);
    state (B,H,P,N) -> (y (B,H,P), new_state)."""
    H = x_t.shape[1]
    bf = _expand_groups(b_t[:, None].astype(jnp.float32), H)[:, 0]
    cf = _expand_groups(c_t[:, None].astype(jnp.float32), H)[:, 0]
    a = jnp.exp(log_a_t.astype(jnp.float32))
    s = state.astype(jnp.float32) * a[..., None, None] \
        + x_t.astype(jnp.float32)[..., None] * bf[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", s, cf)
    return y.astype(x_t.dtype), s
