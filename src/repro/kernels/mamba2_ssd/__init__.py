from repro.kernels.mamba2_ssd import ops, ref  # noqa: F401
