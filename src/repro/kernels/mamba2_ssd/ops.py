"""Dispatching wrapper for the Mamba2 SSD scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba2_ssd import ref


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "unroll"))
def ssd(x, log_a, b, c, initial_state=None, *, impl: str = "ref",
        chunk: int = 128, unroll: bool = False):
    if impl == "naive":
        return ref.ssd_naive(x, log_a, b, c, initial_state)
    if impl == "ref":
        return ref.ssd_chunked(x, log_a, b, c, initial_state, chunk=chunk,
                               unroll=unroll)
    if impl == "kernel":
        from repro.kernels.mamba2_ssd import mamba2_ssd
        return mamba2_ssd.ssd_pallas(x, log_a, b, c, initial_state,
                                     chunk=chunk)
    raise ValueError(impl)


ssd_step = ref.ssd_step
