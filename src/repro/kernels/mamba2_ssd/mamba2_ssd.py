"""TPU Pallas kernel for the Mamba2 SSD chunked scan.

Grid: (B, H, num_chunks) with the chunk dimension minormost (sequential per
core); the inter-chunk state (P, N) is carried in f32 VMEM scratch, so HBM
sees each x/b/c chunk exactly once -- the scan's working set (a (Q,P) x
chunk, (Q,N) b/c chunks, the (P,N) state and the (Q,Q) decay matrix) fits
VMEM comfortably at the default Q=128, P=64, N<=256 (~0.5 MB f32).

Intra-chunk work is the quadratic "attention" form (two MXU matmuls); the
inter-chunk recurrence is a rank-Q state update, also a matmul.  Matches
ref.ssd_chunked numerics (same segsum formulation, unconditionally stable:
all exponents <= 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum(log_a):
    """(Q,) -> (Q, Q) lower-tri pairwise sums: out[i,j]=sum_{j<s<=i} log_a[s]."""
    Q = log_a.shape[0]
    cs = jnp.cumsum(log_a)
    diff = cs[:, None] - cs[None, :]
    i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    return jnp.where(i >= j, diff, -jnp.inf)


def _kernel(x_ref, la_ref, b_ref, c_ref, s0_ref, y_ref, sout_ref, s_scr,
            *, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (Q, P)
    la = la_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    b = b_ref[0, :, 0].astype(jnp.float32)        # (Q, N)
    c = c_ref[0, :, 0].astype(jnp.float32)        # (Q, N)
    s = s_scr[...]                                # (P, N)

    # intra-chunk quadratic term
    Lmat = jnp.exp(_segsum(la))                   # (Q, Q), tri
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # (Q,Q)
    y_intra = jax.lax.dot((scores * Lmat).astype(x.dtype), x)     # (Q,P)

    # carry-in term
    cum = jnp.cumsum(la)                          # (Q,)
    decay_in = jnp.exp(cum)[:, None]              # (Q,1)
    y_inter = jax.lax.dot(c * decay_in,
                          s.transpose())          # (Q,N)@(N,P) -> (Q,P)

    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S' = exp(total) S + sum_j decay_to_end[j] x_j b_j^T
    total = cum[-1]
    decay_to_end = jnp.exp(total - cum)[:, None]  # (Q,1)
    chunk_state = jax.lax.dot((x * decay_to_end).transpose(), b)  # (P,N)
    s_scr[...] = jnp.exp(total) * s + chunk_state

    @pl.when(ic == nc - 1)
    def _final():
        sout_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_pallas(x, log_a, b, c, initial_state=None, *, chunk: int = 128,
               interpret: bool = True):
    """Same contract as ref.ssd_chunked. x (B,L,H,P); log_a (B,L,H);
    b/c (B,L,G,N); state (B,H,P,N)."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    group = (lambda h: h * G // H) if G != H else (lambda h: h)

    kernel = functools.partial(_kernel, nc=nc)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, Q, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda ib, ih, ic: (ib, ic, group(ih), 0)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda ib, ih, ic: (ib, ic, group(ih), 0)),
            pl.BlockSpec((1, 1, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, log_a, b, c, initial_state)
    return y, s_out
