"""TPU Pallas kernel for the RWKV6 WKV recurrence.

Grid: (B, H, num_chunks), chunk dimension minormost; the (K, V) state is
carried in f32 VMEM scratch.  The carry-in contribution for a whole chunk
is one MXU matmul, (r * decay_in)(Q,K) @ S(K,V); the intra-chunk term uses
the sequential per-step loop (numerically exact for arbitrary
data-dependent decay -- the fully-parallel form overflows f32, see
ref.wkv6_chunked).  The loop body is rank-1 work; Q=64 keeps the sequential
fraction small while the (Q,K)x(K,V) matmuls feed the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
            s_scr, *, nc: int, Q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)        # (Q, K)
    k = k_ref[0, :, 0].astype(jnp.float32)        # (Q, K)
    v = v_ref[0, :, 0].astype(jnp.float32)        # (Q, V)
    lw = lw_ref[0, :, 0].astype(jnp.float32)      # (Q, K)
    u = u_ref[0].astype(jnp.float32)              # (K,)
    s_in = s_scr[...]                             # (K, V)

    # carry-in term for every step of the chunk: one MXU matmul
    cum = jnp.cumsum(lw, axis=0)                  # (Q, K)
    decay_in = jnp.exp(cum - lw)                  # prod_{s<=t-1} w, <= 1
    y_inter = jax.lax.dot(r * decay_in, s_in)     # (Q, V)

    # intra-chunk: exact sequential recurrence from zero state
    def step(t, carry):
        s, y = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)     # (1, K)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)     # (1, V)
        wt = jnp.exp(jax.lax.dynamic_slice_in_dim(lw, t, 1, 0))
        kv = kt.transpose() * vt                          # (K, V)
        yt = jax.lax.dot(rt, s + u[:, None] * kv)         # (1, V)
        y = jax.lax.dynamic_update_slice_in_dim(y, yt, t, 0)
        s = s * wt.transpose() + kv
        return s, y

    s_c, y_intra = jax.lax.fori_loop(
        0, Q, step, (jnp.zeros_like(s_in), jnp.zeros((Q, v.shape[1]),
                                                     jnp.float32)))
    y_ref[0, :, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    total = cum[-1]                               # (K,)
    s_scr[...] = jnp.exp(total)[:, None] * s_in + s_c

    @pl.when(ic == nc - 1)
    def _final():
        sout_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, log_w, u, initial_state=None, *, chunk: int = 64,
                interpret: bool = True):
    """Same contract as ref.wkv6_chunked. r/k/log_w (B,L,H,K); v (B,L,H,V);
    u (H,K); state (B,H,K,V)."""
    B, L, H, K = r.shape
    V = v.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    if initial_state is None:
        initial_state = jnp.zeros((B, H, K, V), jnp.float32)

    kernel = functools.partial(_kernel, nc=nc, Q=Q)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, K), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, Q, 1, K), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, Q, 1, V), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, Q, 1, K), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, K), lambda ib, ih, ic: (ih, 0)),
            pl.BlockSpec((1, 1, K, V), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, V), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, K, V), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u, initial_state)
    return y, s_out
