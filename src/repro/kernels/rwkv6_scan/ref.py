"""Pure-jnp oracles for the RWKV6 ("Finch") WKV recurrence.

Contract (shared by ref, naive and Pallas implementations):

    y, final_state = wkv6(r, k, v, log_w, u, initial_state, chunk)

    r:      (B, L, H, K)   receptance
    k:      (B, L, H, K)   key
    v:      (B, L, H, V)   value
    log_w:  (B, L, H, K)   per-step, per-channel log decay (data-dependent!)
    u:      (H, K)         "bonus" for the current token
    state:  (B, H, K, V)

    recurrence:
        y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_naive(r, k, v, log_w, u, initial_state=None, unroll: bool = False):
    """Step-by-step scan; ground-truth oracle for tests."""
    B, L, H, K = r.shape
    V = v.shape[-1]
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = jnp.exp(log_w.astype(jnp.float32))
    uf = u.astype(jnp.float32)
    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp     # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., None] * vt[..., None, :]             # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[..., None] * kv)
        s = s * wt[..., None] + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    s, ys = jax.lax.scan(step, s0, xs, unroll=unroll)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s


def wkv6_chunked(r, k, v, log_w, u, initial_state=None, chunk: int = 64,
                 unroll: bool = False):
    """Chunked WKV6: sequential scan *within* each chunk (vectorized across
    all chunks, so the sequential depth is Q + L/Q instead of L) plus an
    analytic inter-chunk recurrence.

    The fully-parallel intra-chunk form needs exp(+|cumsum log w|) factors
    that overflow f32 for strong data-dependent decay; this hybrid is exact
    and unconditionally stable, and is also the blocked structure the Pallas
    kernel uses.
    """
    B, L, H, K = r.shape
    V = v.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    rf = r.astype(jnp.float32).reshape(B * nc, Q, H, K)
    kf = k.astype(jnp.float32).reshape(B * nc, Q, H, K)
    vf = v.astype(jnp.float32).reshape(B * nc, Q, H, V)
    lw = log_w.astype(jnp.float32).reshape(B * nc, Q, H, K)
    uf = u.astype(jnp.float32)
    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    # intra-chunk term from zero state, all chunks at once
    y_intra, chunk_state = wkv6_naive(rf, kf, vf, lw, uf, unroll=unroll)
    y_intra = y_intra.reshape(B, nc, Q, H, V).astype(jnp.float32)
    chunk_state = chunk_state.reshape(B, nc, H, K, V)

    cum = jnp.cumsum(lw.reshape(B, nc, Q, H, K), axis=2)    # log prod_{s<=t}
    total = cum[:, :, -1]                                   # (B,nc,H,K)
    decay_in = jnp.exp(cum - lw.reshape(B, nc, Q, H, K))    # prod_{s<=t-1} <=1

    # inter-chunk recurrence over nc steps
    def step(s, inp):
        cs, tot = inp
        s_in = s
        s = s * jnp.exp(tot)[..., None] + cs
        return s, s_in

    xs = (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(total, 1, 0))
    s_final, s_prevs = jax.lax.scan(step, s0, xs, unroll=unroll)
    s_prev = jnp.moveaxis(s_prevs, 0, 1)                    # (B,nc,H,K,V)

    # carry-in contribution: r_t . diag(prod_{s<=t-1} w) S_prev
    rr = rf.reshape(B, nc, Q, H, K)
    y_inter = jnp.einsum("bnihk,bnihk,bnhkv->bnihv", rr, decay_in, s_prev)

    y = (y_inter + y_intra).reshape(B, L, H, V).astype(r.dtype)
    return y, s_final


def wkv6_step(r_t, k_t, v_t, log_w_t, u, state):
    """Single decode step. r/k/log_w (B,H,K), v (B,H,V), state (B,H,K,V)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r_t, k_t, v_t))
    wf = jnp.exp(log_w_t.astype(jnp.float32))
    s = state.astype(jnp.float32)
    kv = kf[..., None] * vf[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rf, s + u.astype(jnp.float32)[..., None] * kv)
    s = s * wf[..., None] + kv
    return y.astype(r_t.dtype), s
