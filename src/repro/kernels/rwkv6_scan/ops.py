"""Dispatching wrapper for the RWKV6 WKV scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6_scan import ref


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "unroll"))
def wkv6(r, k, v, log_w, u, initial_state=None, *, impl: str = "ref",
         chunk: int = 64, unroll: bool = False):
    if impl == "naive":
        return ref.wkv6_naive(r, k, v, log_w, u, initial_state,
                              unroll=unroll)
    if impl == "ref":
        return ref.wkv6_chunked(r, k, v, log_w, u, initial_state,
                                chunk=chunk, unroll=unroll)
    if impl == "kernel":
        from repro.kernels.rwkv6_scan import rwkv6_scan
        return rwkv6_scan.wkv6_pallas(r, k, v, log_w, u, initial_state,
                                      chunk=chunk)
    raise ValueError(impl)


wkv6_step = ref.wkv6_step
