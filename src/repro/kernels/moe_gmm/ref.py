"""Pure-jnp oracle for the grouped matmul."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_reference(xe, w):
    """xe (E, C, D) @ w (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(xe.dtype)
