from repro.kernels.moe_gmm import ops, ref  # noqa: F401
