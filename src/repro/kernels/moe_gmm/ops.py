"""MoE FFN built on the grouped-matmul kernel.

Routing/dispatch (scatter-gather, identical to models.moe.moe_dropping)
stays in jnp; the three expert GEMMs run through the Pallas gmm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm.moe_gmm import gmm  # noqa: F401
from repro.kernels.moe_gmm.ref import gmm_reference  # noqa: F401


def moe_ffn(params, x, cfg):
    from repro.models import moe as moe_mod
    from repro.models.mlp import _act

    B, S, D = x.shape
    E = cfg.num_experts
    C = moe_mod._capacity(cfg, S)
    cd = jnp.dtype(cfg.compute_dtype)
    gates, topw, topi = moe_mod._router(params, x, cfg)
    aux = moe_mod.aux_load_balance_loss(gates, topi, E)

    def route_row(x_row, topi_row, topw_row):
        pos, keep = moe_mod._route_positions(topi_row, cfg, C)
        e_flat = topi_row.reshape(-1)
        p_flat = jnp.where(keep, pos, C).reshape(-1)
        tok_flat = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[:, None],
            topi_row.shape).reshape(-1)
        slots = jnp.full((E, C), S, jnp.int32)
        slots = slots.at[e_flat, p_flat].set(tok_flat, mode="drop")
        xe = jnp.take(x_row, slots, axis=0, mode="fill",
                      fill_value=0).astype(cd)
        return xe, (e_flat, p_flat, keep, topw_row)

    xe, meta = jax.vmap(route_row)(x, topi, topw)      # (B,E,C,D)
    Bb, _, _, _ = xe.shape
    xe2 = xe.reshape(B * E, C, D)

    def tile(w):
        return jnp.broadcast_to(w[None], (B,) + w.shape).reshape(
            (B * E,) + w.shape[1:]).astype(cd)

    act = _act(cfg.act)
    g = gmm(xe2, tile(params["wi_gate"]))
    u = gmm(xe2, tile(params["wi_up"]))
    ye = gmm((act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(cd),
             tile(params["wo"]))
    ye = ye.reshape(B, E, C, D)

    def combine_row(ye_row, m):
        e_flat, p_flat, keep, topw_row = m
        K = topw_row.shape[-1]
        yk = ye_row.reshape(E * C, D)
        flat_idx = jnp.where(keep.reshape(-1), e_flat * C + p_flat, E * C)
        y_sel = jnp.take(yk, flat_idx, axis=0, mode="fill", fill_value=0)
        w = (topw_row.reshape(-1, 1)
             * keep.reshape(-1, 1)).astype(y_sel.dtype)
        return jnp.sum((y_sel * w).reshape(S, K, D), axis=1)

    y = jax.vmap(combine_row)(ye, meta)
    return y.astype(x.dtype), aux
