"""TPU Pallas grouped (per-expert) matmul for MoE FFNs.

Computes ye[e] = xe[e] @ w[e] for every expert e over capacity-grouped
token slots: xe (E, C, D) x w (E, D, F) -> (E, C, F).

Grid: (E, C/bc, F/bf, D/bd) with the contraction dimension minormost; a
f32 VMEM accumulator carries partial sums over the D tiles, so each output
tile is written to HBM once.  Tile defaults (bc, bf, bd) = (128, 128, 512)
are MXU-aligned; VMEM footprint = bc*bd + bd*bf (bf16) + bc*bf (f32)
~ 0.25 MB.  Empty slots (capacity padding) multiply zeros -- the dispatch
layer masks them, so no flag plumbing is needed here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, nd: int):
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                                   # (bc, bd)
    w = w_ref[0]                                   # (bd, bf)
    acc_scr[...] += jax.lax.dot(
        x, w, preferred_element_type=jnp.float32)  # MXU f32 accumulate

    @pl.when(kd == nd - 1)
    def _final():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "block_d", "interpret"))
def gmm(xe, w, *, block_c: int = 128, block_f: int = 128,
        block_d: int = 512, interpret: bool = True):
    """xe (E, C, D) @ w (E, D, F) -> (E, C, F)."""
    E, C, D = xe.shape
    _, _, F = w.shape
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0, (C, F, D)
    nd = D // bd

    kernel = functools.partial(_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, C // bc, F // bf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(xe, w)
