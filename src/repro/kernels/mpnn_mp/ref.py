"""Pure-jnp oracle for the MPNN message step."""
from __future__ import annotations

import jax.numpy as jnp


def message_pass_reference(h, edge_mat, adj):
    """h (B,N,Hd); edge_mat (B,N,N,Hd,Hd); adj (B,N,N) -> (B,N,Hd)."""
    return jnp.einsum("bijkl,bjl,bij->bik",
                      edge_mat.astype(jnp.float32),
                      h.astype(jnp.float32),
                      adj.astype(jnp.float32)).astype(h.dtype)
