from repro.kernels.mpnn_mp import ops, ref  # noqa: F401
