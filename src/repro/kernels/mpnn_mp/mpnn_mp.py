"""TPU Pallas kernel for the dense-adjacency MPNN message step (the
paper's own surrogate hot-spot: §II-B runs 10^5+ MPNN inferences per
campaign batch).

messages[i] = sum_j adj[i,j] * (edge[i,j] @ h[j])

Grid: (B,) -- one molecule per grid step.  QM9-scale molecules are tiny
(N<=32, Hd<=128): the whole (N,N,Hd,Hd) edge block (32*32*128*128*2B = 32MB
at the extreme; 1MB at the surrogate's N=16, Hd=64) streams through VMEM
once and the contraction is reorganized as a single (N*Hd) x (N*Hd -> Hd)
matmul per target atom batch to hit the MXU instead of N^2 small matvecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, e_ref, a_ref, o_ref):
    h = h_ref[0].astype(jnp.float32)              # (N, Hd)
    e = e_ref[0].astype(jnp.float32)              # (N, N, Hd, Hd)
    a = a_ref[0].astype(jnp.float32)              # (N, N)
    N, Hd = h.shape
    # weight edges by adjacency, then contract:
    # m[i, k] = sum_{j, l} (a[i,j] e[i,j,k,l]) h[j,l]
    ew = e * a[:, :, None, None]
    # reshape to one big matmul: (N, N*Hd? ) -- per-target-atom matmul:
    # (N, [j,l] = N*Hd) x (N*Hd,) ... vectorized over k via dot_general
    ew2 = jnp.transpose(ew, (0, 2, 1, 3)).reshape(N * Hd, N * Hd)
    m = jax.lax.dot(ew2, h.reshape(N * Hd, 1))    # (N*Hd, 1)
    o_ref[0] = m.reshape(N, Hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def message_pass_pallas(h, edge_mat, adj, *, interpret: bool = True):
    """h (B,N,Hd); edge_mat (B,N,N,Hd,Hd); adj (B,N,N) -> (B,N,Hd)."""
    B, N, Hd = h.shape
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N, Hd), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, N, N, Hd, Hd), lambda b: (b, 0, 0, 0, 0)),
            pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N, Hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, Hd), h.dtype),
        interpret=interpret,
    )(h, edge_mat, adj)
