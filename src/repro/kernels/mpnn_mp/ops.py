"""Dispatching wrapper for the MPNN message step."""
from __future__ import annotations

from repro.kernels.mpnn_mp.mpnn_mp import message_pass_pallas
from repro.kernels.mpnn_mp.ref import message_pass_reference  # noqa: F401


def message_pass(h, edge_mat, adj, *, impl: str = "kernel"):
    if impl == "kernel":
        return message_pass_pallas(h, edge_mat, adj)
    return message_pass_reference(h, edge_mat, adj)
