"""The paper's own model: the MPNN-ensemble surrogate used by the
electrolyte-design application (§II-B: 16 MPNNs trained on QC results)."""
from repro.models.mpnn import MPNNConfig

CONFIG = MPNNConfig(
    num_atom_types=8,
    num_bond_types=4,
    hidden=64,
    message_steps=3,
    readout_hidden=128,
    ensemble=16,             # the paper's ensemble size
)


def reduced() -> MPNNConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, hidden=16, message_steps=2,
                               readout_hidden=32, ensemble=4)
