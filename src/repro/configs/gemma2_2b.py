"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
— local+global alternating attention, logit softcaps, sandwich norms,
tied embeddings [arXiv:2408.00118; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256_000,
    head_dim=256,
    sliding_window=4096,
    local_global_period=2,      # [local, global] x 13
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norm=True,
    emb_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    act="gelu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-2b-reduced",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32, sliding_window=32,
        attn_chunk=64, remat="none",
    )
