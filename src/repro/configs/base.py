"""Configuration system: model / shape / sharding / train configs + registry.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` that
exposes ``CONFIG`` (the exact published configuration) and ``reduced()`` (a
tiny same-family config for CPU smoke tests).  ``get_config`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int             # decoder layers for enc-dec
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                   # dense MLP hidden (per-expert hidden for MoE)
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // num_heads

    # Attention variants
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None   # local-attention window size
    local_global_period: int = 0           # >0: every Nth layer is global (rest local)
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM / hybrid
    ssm_state: int = 0          # Mamba2 d_state
    ssm_heads: int = 0          # Mamba2 heads (0 => derived)
    ssm_expand: int = 2         # Mamba2 expansion factor
    ssm_conv: int = 4           # conv1d width
    attn_every: int = 0         # zamba2: shared attn block after every Nth layer
    rwkv: bool = False
    rwkv_head_size: int = 64

    # Encoder-decoder
    encoder_layers: int = 0     # >0 => enc-dec; num_layers is the decoder depth

    # Misc architecture
    act: str = "silu"           # silu => SwiGLU, gelu => GeGLU
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    post_norm: bool = False     # gemma2-style additional post-block norms
    emb_scale: bool = False     # gemma-style sqrt(d_model) embedding scale

    # Numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # Implementation switches (perf levers; do not change semantics)
    attn_impl: str = "ref"      # ref (jnp, chunked) | kernel (pallas, TPU)
    attn_chunk: int = 1024      # KV chunk for the chunked-ref path
    moe_impl: str = "dropping"  # dense | dropping (capacity-based EP dispatch)
    remat: str = "block"        # none | block (recompute everything) |
                                # policy (save matmul outputs, recompute
                                # elementwise only -- cheaper backward)
    scan_layers: bool = True    # stack layers with lax.scan (small HLO)
    scan_unroll: bool = False   # fully unroll scans (dry-run cost probes:
                                # XLA cost_analysis counts while bodies once)
    seq_parallel: bool = False  # Megatron-SP: residual stream sharded over
                                # the model axis between blocks (all-reduce
                                # -> reduce-scatter + all-gather)
    fuse_ffn: bool = True
    fuse_kv: bool = True        # K/V projections fused via a stacked leading
                                # axis (never concat across the head dim:
                                # that miscompiles when heads are sharded)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.rwkv

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch is paired with these four shapes.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}

# long_500k requires sub-quadratic context handling: run only for SSM /
# hybrid / linear-attention families (see DESIGN.md §4).
LONG_CONTEXT_FAMILIES = ("hybrid", "ssm")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell; reason when not."""
    if shape.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, "long_500k skipped: full-attention arch (sub-quadratic required)"
    return True, ""


# ---------------------------------------------------------------------------
# Sharding configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardingConfig:
    mode: str = "dp_tp"        # dp_tp (params replicated over data) | fsdp_tp
    zero: int = 1              # 0: opt state like params; 1: opt sharded over data
    shard_cache_seq: bool = True   # decode: shard KV cache sequence over model axis
    grad_compress: str = "none"    # none | bf16 | int8_ef (cross-pod hop)
    remat_override: Optional[str] = None
    microbatches: int = 1      # gradient accumulation steps


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "granite-20b",
    "gemma2-2b",
    "qwen3-8b",
    "internlm2-1.8b",
    "zamba2-1.2b",
    "kimi-k2-1t-a32b",
    "llama4-scout-17b-a16e",
    "rwkv6-3b",
    "qwen2-vl-72b",
    "seamless-m4t-medium",
]


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(_module_name(arch_id))
    return mod.reduced() if reduced else mod.CONFIG


def list_archs():
    return list(ARCH_IDS)


# ---------------------------------------------------------------------------
# Analytic parameter / FLOP accounting (used by roofline + sanity tests)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count for the configured model."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qo = cfg.num_heads * hd
    kv = cfg.num_kv_heads * hd
    attn = d * qo + 2 * d * kv + qo * d  # wq, wk, wv, wo
    if cfg.qk_norm:
        attn += 2 * hd
    gated = cfg.act in ("silu", "gelu")
    mlp_dense = (3 if gated else 2) * d * cfg.d_ff

    def block_norms():
        return (4 if cfg.post_norm else 2) * d

    total = 0
    if cfg.rwkv:
        # time-mix: r,k,v,g,o (d*d each) + decay/low-rank (approx) + channel mix
        tmix = 5 * d * d + 2 * d * 32 * 2  # lora-ish decay/mix params (approx)
        cmix = 2 * d * int(cfg.d_ff)
        total += cfg.num_layers * (tmix + cmix + 2 * d)
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * d
        mamba = (d * (2 * d_inner + 2 * cfg.ssm_state)  # in_proj(z,x) + B,C
                 + d_inner * cfg.ssm_conv                # conv
                 + d_inner                               # dt bias (per channel head)
                 + d_inner * d)                          # out_proj
        total += cfg.num_layers * (mamba + block_norms())
        n_attn = cfg.num_layers // max(cfg.attn_every, 1) if cfg.attn_every else 0
        if n_attn:
            total += attn + mlp_dense + block_norms()    # one shared block
    else:
        if cfg.is_moe:
            per_expert = (3 if gated else 2) * d * cfg.d_ff
            ffn = cfg.num_experts * per_expert + d * cfg.num_experts  # + router
        else:
            ffn = mlp_dense
        layers = cfg.num_layers + cfg.encoder_layers
        total += layers * (attn + ffn + block_norms())
        if cfg.encoder_layers:  # decoder cross-attention
            total += cfg.num_layers * (d * qo + 2 * d * kv + qo * d + d)
    total += cfg.vocab_size * d          # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d      # lm head
    total += d                           # final norm
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top-k experts only)."""
    if not cfg.is_moe:
        return param_count(cfg)
    dense_like = param_count(cfg)
    gated = cfg.act in ("silu", "gelu")
    per_expert = (3 if gated else 2) * cfg.d_model * cfg.d_ff
    layers = cfg.num_layers + cfg.encoder_layers
    inactive = layers * (cfg.num_experts - cfg.num_experts_per_token) * per_expert
    return int(dense_like - inactive)


def model_flops_per_token(cfg: ModelConfig, seq_len: int, training: bool) -> float:
    """MODEL_FLOPS/token = 6*N_active (train) or 2*N_active (fwd) + attention."""
    n = active_param_count(cfg) - cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    mult = 6.0 if training else 2.0
    flops = mult * n
    # attention score flops: 2 * 2 * seq * qo per token (causal halves it)
    if not cfg.is_attention_free:
        qo = cfg.num_heads * cfg.resolved_head_dim
        window = seq_len
        if cfg.sliding_window and not cfg.local_global_period:
            window = min(seq_len, cfg.sliding_window)
        flops += mult / 1.5 * 2 * qo * (window / 2)
    # lm head
    flops += mult * cfg.d_model * cfg.vocab_size
    return flops
