"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1 / MQA) d_ff=24576
vocab=49152 — llama-arch code model [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,          # multi-query attention
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    rope_theta=10_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-20b-reduced",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=1,
        d_ff=256, vocab_size=512, head_dim=32, attn_chunk=64, remat="none",
    )
