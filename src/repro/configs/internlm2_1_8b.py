"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 [arXiv:2403.17297; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    head_dim=128,
    rope_theta=1_000_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="internlm2-1.8b-reduced",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32, attn_chunk=64, remat="none",
    )
