"""seamless-m4t-medium [audio]: enc-dec, 12+12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 [arXiv:2308.11596; hf].

Backbone only per the assignment: the speech frontend is a stub and
``input_specs()`` provides precomputed frame embeddings for the encoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,           # decoder
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    head_dim=64,
    rope_theta=10_000.0,
    act="gelu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="seamless-m4t-medium-reduced",
        num_layers=2, encoder_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, head_dim=32,
        attn_chunk=64, remat="none",
    )
