"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
— qk-norm, GQA [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-8b-reduced",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32, attn_chunk=64, remat="none",
    )
