"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
(per-expert hidden) vocab=202048, MoE 16 experts top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Note: released Scout adds a shared expert and interleaves dense layers;
the assignment table specifies a uniform MoE 16e top-1 stack, which is what
we implement."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,               # per-expert hidden
    vocab_size=202_048,
    head_dim=128,
    num_experts=16,
    num_experts_per_token=1,
    capacity_factor=1.25,
    rope_theta=500_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama4-scout-17b-a16e-reduced",
        num_layers=3, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512, head_dim=32, num_experts=4,
        num_experts_per_token=1, attn_chunk=64, remat="none",
    )
