"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only per the assignment: the vision frontend is a stub and
``input_specs()`` provides precomputed patch embeddings plus (3, B, S)
M-RoPE positions (temporal / height / width)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    head_dim=128,
    mrope_sections=(16, 24, 24),   # sums to head_dim/2
    rope_theta=1_000_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-vl-72b-reduced",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32, mrope_sections=(4, 6, 6),
        attn_chunk=64, remat="none",
    )
