"""rwkv6-3b [ssm/linear-attention]: 32L d_model=2560 (attention-free)
d_ff=8960 vocab=65536 — "Finch", data-dependent decay [arXiv:2404.05892; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # d_model / rwkv_head_size
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv=True,
    rwkv_head_size=64,
    act="relu",              # squared-relu channel mix (set in rwkv6.py)
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="rwkv6-3b-reduced",
        num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, rwkv_head_size=32, attn_chunk=64,
        remat="none",
    )
