"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32, MHA) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

The shared attention block (one parameter set, applied after every
`attn_every` Mamba2 layers) is the Zamba2 signature; see DESIGN.md for the
simplifications vs. the released checkpoints (no LoRA adapters per
application, single shared block instead of two alternating)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=6,           # 6 full groups of 6 + a 2-layer tail
    rope_theta=10_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-1.2b-reduced",
        num_layers=5, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32, ssm_state=16, attn_every=2,
        attn_chunk=64, remat="none",
    )
