"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per-expert hidden) vocab=163840, MoE 384 experts top-8 — trillion-param
MoE per the assignment's paper table [arXiv:2501.kimi2; unverified].

Note: the released Kimi K2 uses MLA attention; the assignment table
specifies GQA kv=8, which is what we implement (the assignment config is
authoritative for the dry-run)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,               # per-expert hidden
    vocab_size=163_840,
    head_dim=112,            # d_model / num_heads
    num_experts=384,
    num_experts_per_token=8,
    capacity_factor=1.25,
    rope_theta=50_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="kimi-k2-1t-a32b-reduced",
        num_layers=3, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512, head_dim=32, num_experts=8,
        num_experts_per_token=2, attn_chunk=64, remat="none",
    )
