"""Micro-batch assembly for the inference shard: pure bookkeeping.

Requests are bucketed by **padded prompt length** (the smallest declared
bucket that fits) so every micro-batch the shard hands to the engine has
one static prompt shape, and batch sizes are padded up to powers of two
(capped at ``max_batch``) so the engine's jitted executables are reused
across calls instead of recompiled per ragged size -- pad-bounded means
the wasted work is bounded by the bucket granularity, never unbounded
ragged padding.

A bucket flushes when it can fill a whole ``max_batch``, when its oldest
request has waited ``max_batch_delay`` (the latency/occupancy knob:
0 serves singles immediately, larger values trade first-token latency
for fuller batches), or on an explicit ``force`` (shutdown drain).

``DecodeGroup`` tracks one prefilled micro-batch through its decode
steps: per-row generation targets, which rows already finished (streamed
back early), and when enough rows have retired that the survivors fit a
strictly smaller batch bucket -- the compaction that makes freed slots
stop costing decode FLOPs and frees capacity for the next admission.

Everything here is plain Python + numpy: no jax, no transport.  The
shard composes it with an engine and a broker channel; the tests drive
it directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

DEFAULT_PROMPT_BUCKETS = (16, 32, 64, 128)


def prompt_bucket(length: int, buckets: Sequence[int]) -> int:
    """The smallest declared bucket that fits ``length``."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(
        f"prompt length {length} exceeds the largest bucket"
        f" {max(buckets)}; raise ServeSpec.prompt_buckets")


def batch_bucket(n: int, max_batch: int) -> int:
    """Pad a batch size up to the next power of two, capped at
    ``max_batch`` -- the set of batch shapes the engine ever sees (and
    therefore ever compiles) is {1, 2, 4, ..., max_batch}."""
    if n <= 0:
        raise ValueError("empty batch")
    b = 1
    while b < n and b < max_batch:
        b <<= 1
    return min(b, max_batch)


@dataclass
class InferenceRequest:
    """One queued prompt, decoded from its request envelope."""

    task_id: str
    tokens: List[int]
    max_new: int
    enqueue_t: float                      # local receive time (deadlines)
    lease: Optional[int] = None           # detached request-channel lease
    meta: dict = field(default_factory=dict)


@dataclass
class MicroBatch:
    """Requests sharing one padded prompt shape, ready for one prefill."""

    bucket: int                           # padded prompt length
    requests: List[InferenceRequest]

    def padded_tokens(self, padded_b: Optional[int] = None,
                      pad_id: int = 0) -> np.ndarray:
        """(padded_b, bucket) int32 prompt matrix.  Prompts are
        left-padded to the bucket (the generation position must be the
        last *real* token; pad positions participate in attention --
        the same bucketed simplification the engine's docstring
        records).  Batch rows beyond the real requests repeat row 0, so
        padding rows trigger no new compilation and their outputs are
        simply dropped."""
        n = len(self.requests)
        b = n if padded_b is None else padded_b
        out = np.full((b, self.bucket), pad_id, dtype=np.int32)
        for i, r in enumerate(self.requests):
            out[i, self.bucket - len(r.tokens):] = r.tokens
        if b > n:
            out[n:] = out[0]
        return out

    @property
    def max_new(self) -> int:
        return max(r.max_new for r in self.requests)


class MicroBatcher:
    """Accumulates requests into per-bucket queues and decides when a
    micro-batch is worth flushing.  Single-threaded by design: the
    shard's serve loop is the only caller (admission happens between
    decode steps, not concurrently with them)."""

    def __init__(self, *, max_batch: int = 32,
                 prompt_buckets: Sequence[int] = DEFAULT_PROMPT_BUCKETS,
                 max_batch_delay: float = 0.02):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.prompt_buckets = tuple(sorted(prompt_buckets))
        self.max_batch_delay = max_batch_delay
        self._pending: Dict[int, List[InferenceRequest]] = {}

    def add(self, req: InferenceRequest) -> None:
        b = prompt_bucket(len(req.tokens), self.prompt_buckets)
        self._pending.setdefault(b, []).append(req)

    def pending_count(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def next_deadline(self) -> Optional[float]:
        """When the oldest pending request must flush (its enqueue time
        plus the delay knob); None with nothing pending.  The serve loop
        bounds its idle wait by this so a partial batch is never
        stranded behind an empty queue."""
        oldest = None
        for reqs in self._pending.values():
            for r in reqs:
                if oldest is None or r.enqueue_t < oldest:
                    oldest = r.enqueue_t
        return None if oldest is None else oldest + self.max_batch_delay

    def pop_ready(self, tnow: float, force: bool = False
                  ) -> List[MicroBatch]:
        """Flush every bucket that can fill a full ``max_batch`` (as
        many times as it can), plus -- when its oldest request is past
        the delay deadline, or ``force`` -- whatever partial batch
        remains.  FIFO within a bucket."""
        out: List[MicroBatch] = []
        for b in sorted(self._pending):
            reqs = self._pending[b]
            while len(reqs) >= self.max_batch:
                out.append(MicroBatch(b, reqs[:self.max_batch]))
                del reqs[:self.max_batch]
            if reqs and (force
                         or tnow >= reqs[0].enqueue_t + self.max_batch_delay):
                out.append(MicroBatch(b, list(reqs)))
                reqs.clear()
            if not reqs:
                del self._pending[b]
        return out


class DecodeGroup:
    """Bookkeeping for one prefilled micro-batch while it decodes.

    Rows share a start position (they were prefilled together at one
    padded prompt length), so per-row progress differs only through
    per-row ``max_new``: a row whose target is reached retires early and
    its tokens stream back immediately.  ``compaction`` reports when the
    surviving rows fit a strictly smaller batch bucket; the shard then
    gathers the engine state down to those rows (slot reuse: retired
    slots stop costing decode compute, and the freed budget admits the
    next prefill sooner)."""

    def __init__(self, mb: MicroBatch, first_tokens: Sequence[int],
                 max_batch: int):
        self.bucket = mb.bucket
        self.max_batch = max_batch
        self.rows = list(mb.requests)
        # rows[i] lives at engine-state batch row slots[i]; the mapping
        # stays identity until a compaction gathers the state down to
        # the survivors (reset_slots), and diverges in between because
        # retired rows leave holes the engine keeps computing
        self.slots = list(range(len(self.rows)))
        self.outputs: List[List[int]] = [[int(first_tokens[s])]
                                         for s in self.slots]
        self.steps = 1                     # tokens generated per live row

    def __len__(self) -> int:
        return len(self.rows)

    @property
    def done(self) -> bool:
        return not self.rows

    def max_remaining(self) -> int:
        return max((r.max_new - self.steps for r in self.rows), default=0)

    def finished(self) -> List[tuple]:
        """(request, generated_tokens) for rows that reached their
        target -- call after the prefill and after every decode step."""
        return [(r, self.outputs[i]) for i, r in enumerate(self.rows)
                if r.max_new <= self.steps]

    def record_step(self, next_tokens: Sequence[int]) -> None:
        """Fold one decode step's per-slot tokens into the outputs.
        Rows already at their target ignore the extra token (the engine
        keeps computing the padded batch; the row is just done)."""
        for i, r in enumerate(self.rows):
            if r.max_new > self.steps:
                self.outputs[i].append(int(next_tokens[self.slots[i]]))
        self.steps += 1

    def retire_finished(self) -> None:
        """Drop finished rows from the bookkeeping.  Their engine slots
        become holes that keep computing until (and unless) a compaction
        gathers the state down to ``self.slots``."""
        keep = [i for i, r in enumerate(self.rows) if r.max_new > self.steps]
        self.rows = [self.rows[i] for i in keep]
        self.outputs = [self.outputs[i] for i in keep]
        self.slots = [self.slots[i] for i in keep]

    def compaction(self, padded_b: int) -> Optional[int]:
        """The smaller padded batch the survivors fit, or None when
        shrinking wouldn't change the executable shape.  ``padded_b`` is
        the engine state's current batch dimension.  On a gather the
        caller re-packs state rows to ``self.slots`` order and then
        calls ``reset_slots``."""
        if not self.rows:
            return None
        target = batch_bucket(len(self.rows), self.max_batch)
        return target if target < padded_b else None

    def reset_slots(self) -> None:
        self.slots = list(range(len(self.rows)))
