"""Batched serving engine: prefill + KV-cache decode.

Requests are grouped into equal-prompt-length micro-batches (bucketed
continuous batching; per-row ragged prompts would need scatter cache
writes -- see DESIGN.md simplifications).  The engine jits one prefill and
one decode program per (batch, prompt_len) bucket and reuses them across
calls (the warm-executable cache that plays the role of the paper's warm
Python workers).

Two ways to drive it:

- ``generate``: run a whole batch to completion (the original per-call
  library API).
- the stepwise triple ``prefill_batch`` / ``decode_batch`` /
  ``gather_rows``: what the inference shard (``serving/shard.py``) uses
  for continuous batching -- admit a new prefill between other groups'
  decode steps, stream rows out as they finish, and gather a group's
  surviving rows into a smaller batch bucket (slot reuse) so retired
  sequences stop costing decode FLOPs.

Timing honesty: the first ``generate`` call for a given (batch,
prompt_len, max_new) shape triggers XLA compilation, and jax dispatch is
asynchronous -- so the stop-clock only runs after ``block_until_ready``,
and a first-per-shape call's wall goes to ``stats["compile_wall"]``
(warmup), not ``stats["wall"]``.  ``throughput()`` is therefore
steady-state tokens/sec over warm executables only.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclass
class GenState:
    """One decode group's device state between steps."""

    cache: object                 # pytree; every leaf leads with batch
    cur: jnp.ndarray              # (B, 1) last emitted token per row
    pos: int                      # tokens already written to the cache
    reserve: int                  # cache capacity (prompt + generation)
    padded_b: int                 # current batch dimension


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_new: int = 32):
        self.cfg = cfg
        self.params = params
        self.max_new = max_new
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, c, t, n: api.decode_step(p, cfg, c, t, n))
        self._warm: set = set()   # (B, S, max_new) shapes already compiled
        self.stats = {"prefill_calls": 0, "decode_steps": 0,
                      "tokens_out": 0, "wall": 0.0, "compile_wall": 0.0,
                      "warm_tokens": 0}

    # -- stepwise API (continuous batching) ---------------------------------

    def prefill_batch(self, tokens: np.ndarray, *,
                      reserve: Optional[int] = None,
                      frames: Optional[np.ndarray] = None
                      ) -> tuple:
        """Prefill one equal-length micro-batch and reserve cache room
        for generation.  tokens (B, S) -> ((B,) first generated tokens,
        GenState positioned for decode)."""
        B, S = tokens.shape
        reserve = reserve if reserve is not None else S + self.max_new
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if self.cfg.is_encdec:
            if frames is None:
                frames = np.zeros((B, S, self.cfg.d_model), np.float32)
            batch["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, batch)
        cache = api.grow_cache(self.cfg, cache, reserve)
        self.stats["prefill_calls"] += 1
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        state = GenState(cache=cache, cur=first[:, None], pos=S,
                         reserve=reserve, padded_b=B)
        self.stats["tokens_out"] += int(B)
        return np.asarray(first), state

    def decode_batch(self, state: GenState) -> np.ndarray:
        """One decode step for every row of the group; returns the (B,)
        next tokens and advances the state."""
        if state.pos >= state.reserve:
            raise ValueError(
                f"decode past reserved cache length {state.reserve}")
        logits, state.cache = self._decode(
            self.params, state.cache, state.cur,
            jnp.asarray(state.pos, jnp.int32))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        state.cur = nxt[:, None]
        state.pos += 1
        self.stats["decode_steps"] += 1
        self.stats["tokens_out"] += int(state.padded_b)
        return np.asarray(nxt)

    def gather_rows(self, state: GenState, rows: Sequence[int]) -> GenState:
        """Slot reuse: re-pack the group's state down to ``rows`` (engine
        batch indices, typically the survivors padded to a smaller batch
        bucket).  Decode cost drops to the new batch shape from the next
        step on."""
        idx = jnp.asarray(list(rows), jnp.int32)
        # every cache family is stacked over layers: leaves are
        # (num_layers, batch, ...), so the batch gather is along axis 1
        cache = jax.tree_util.tree_map(
            lambda x: jnp.take(x, idx, axis=1), state.cache)
        return GenState(cache=cache, cur=state.cur[idx], pos=state.pos,
                        reserve=state.reserve, padded_b=len(rows))

    # -- run-to-completion API ----------------------------------------------

    def generate(self, tokens: np.ndarray, *, max_new: Optional[int] = None,
                 frames: Optional[np.ndarray] = None) -> np.ndarray:
        """tokens (B, S) equal-length prompts -> (B, S + max_new)."""
        t_start = time.perf_counter()
        max_new = max_new or self.max_new
        B, S = tokens.shape
        first, state = self.prefill_batch(tokens, reserve=S + max_new,
                                          frames=frames)
        out = [state.cur[:, 0]]
        for _ in range(max_new - 1):
            self.decode_batch(state)
            out.append(state.cur[:, 0])
        gen = jnp.stack(out, axis=1)
        # the stop-clock only runs once the device is done -- without the
        # sync, async dispatch would make throughput() a dispatch rate
        gen = jax.block_until_ready(gen)
        elapsed = time.perf_counter() - t_start
        key = (B, S, max_new)
        if key in self._warm:
            self.stats["wall"] += elapsed
            self.stats["warm_tokens"] += int(B * max_new)
        else:
            self._warm.add(key)
            self.stats["compile_wall"] += elapsed
        return np.concatenate([tokens, np.asarray(gen)], axis=1)

    def throughput(self) -> float:
        """Steady-state tokens/sec: warm-executable calls only (first
        call per shape is compile-dominated and counted in
        ``stats["compile_wall"]``)."""
        return self.stats["warm_tokens"] / max(self.stats["wall"], 1e-9)
