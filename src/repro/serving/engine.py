"""Batched serving engine: prefill + KV-cache decode.

Requests are grouped into equal-prompt-length micro-batches (bucketed
continuous batching; per-row ragged prompts would need scatter cache
writes -- see DESIGN.md simplifications).  The engine jits one prefill and
one decode program per (batch, prompt_len) bucket and reuses them across
calls (the warm-executable cache that plays the role of the paper's warm
Python workers).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_new: int = 32):
        self.cfg = cfg
        self.params = params
        self.max_new = max_new
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, c, t, n: api.decode_step(p, cfg, c, t, n))
        self.stats = {"prefill_calls": 0, "decode_steps": 0,
                      "tokens_out": 0, "wall": 0.0}

    def generate(self, tokens: np.ndarray, *, max_new: Optional[int] = None,
                 frames: Optional[np.ndarray] = None) -> np.ndarray:
        """tokens (B, S) equal-length prompts -> (B, S + max_new)."""
        t_start = time.perf_counter()
        max_new = max_new or self.max_new
        B, S = tokens.shape
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if self.cfg.is_encdec:
            if frames is None:
                frames = np.zeros((B, S, self.cfg.d_model), np.float32)
            batch["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, batch)
        cache = api.grow_cache(self.cfg, cache, S + max_new)
        self.stats["prefill_calls"] += 1

        out = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
        cur = out[-1][:, None]
        for step in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, cur,
                                         jnp.asarray(S + step, jnp.int32))
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(cur[:, 0])
            self.stats["decode_steps"] += 1
        gen = jnp.stack(out, axis=1)
        self.stats["tokens_out"] += int(B * max_new)
        self.stats["wall"] += time.perf_counter() - t_start
        return np.concatenate([tokens, np.asarray(gen)], axis=1)

    def throughput(self) -> float:
        return self.stats["tokens_out"] / max(self.stats["wall"], 1e-9)
