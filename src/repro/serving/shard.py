"""Inference shard: continuous-batching model serving as a fabric role.

A shard is a forked consumer process (supervised like Value Server
shards, declared per host via ``HostSpec.inference_shards``) that drains
one dedicated request topic through the ordinary lease/ack broker
protocol and serves the requests over a warm ``Engine``:

- requests are bucketed by prompt length into pad-bounded micro-batches
  (``serving.batcher``), flushed when full or when the oldest request
  has waited ``max_batch_delay_ms`` -- the latency/occupancy knob;
- the serve loop runs **continuous batching**: between any two decode
  steps it polls the request channel and admits newly arrived
  micro-batches as fresh prefills, so a request never waits for an
  unrelated batch to run to completion;
- rows that reach their per-request ``max_new`` stream back immediately,
  and when the survivors of a group fit a strictly smaller batch bucket
  the engine state is gathered down (slot reuse: retired slots stop
  costing decode FLOPs);
- every result is published under the fused put-claim, so the
  exactly-once and checkpoint/resume guarantees of the dispatch fabric
  carry over unchanged.

Lease discipline (the crash story): a drained request batch's lease is
**detached** (``Channel.detach_lease``) and held -- heartbeat-renewed --
until every request it delivered has had its result published (claim won
*or* lost); only then is the lease acked.  A shard SIGKILLed mid-batch
therefore leaves its leases unacked: they expire, every undelivered
request redelivers to a surviving (or restarted) shard, and any row the
dead shard already streamed out is deduped by the claim on the result
put.  Zero lost, zero duplicated.

This module imports no jax at module scope: fabric processes can import
``ServeSpec``/``InferenceClient`` without dragging in the accelerator
stack (the engine is built lazily, inside the shard process).
"""
from __future__ import annotations

import multiprocessing
import os
import signal
import threading
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro import observability as obs
from repro.core import message as msg
from repro.core.transport.base import Envelope, Transport
from repro.serving.batcher import (DEFAULT_PROMPT_BUCKETS, DecodeGroup,
                                   InferenceRequest, MicroBatch,
                                   MicroBatcher, batch_bucket)
from repro.utils.timing import now

_mp = multiprocessing.get_context("fork")

DEFAULT_INFER_TOPIC = "infer"

#: how long the serve loop waits on the request channel between decode
#: steps while groups are active -- the admission poll.  Returns
#: immediately when requests are queued; otherwise bounds the stall a
#: decode step pays to check for new arrivals.
ADMIT_POLL = 0.002


def default_engine_factory(arch: str = "internlm2-1.8b", *,
                           reduced: bool = True, seed: int = 0,
                           max_new: int = 32) -> Callable:
    """An engine factory for the reduced reference model.  Returned as a
    closure so the (heavy, jax-importing) build happens inside the shard
    process, never in the fabric process that declares the spec."""
    def build():
        import jax
        from repro.configs.base import get_config
        from repro.models import api
        from repro.serving.engine import Engine
        cfg = get_config(arch, reduced=reduced)
        params = api.init_params(cfg, jax.random.PRNGKey(seed))
        return Engine(cfg, params, max_new=max_new)
    return build


@dataclass
class ServeSpec:
    """Everything a shard needs to serve one inference topic.  Pure data
    plus a factory callable (fork-inherited, like launcher methods)."""

    topic: str = DEFAULT_INFER_TOPIC
    engine_factory: Optional[Callable] = None   # () -> Engine-like
    max_batch: int = 32
    prompt_buckets: Sequence[int] = DEFAULT_PROMPT_BUCKETS
    #: deadline knob: how long a partial micro-batch may wait for
    #: company before it is flushed anyway.  0 serves singles with
    #: minimum latency; larger values trade first-token latency for
    #: batch occupancy (tokens/sec).
    max_batch_delay_ms: float = 20.0
    #: per-request ``max_new`` ceiling; also bounds the cache reserve
    #: buckets so decode executables are shared across groups.
    max_new_cap: int = 64
    default_max_new: int = 8

    def make_engine(self):
        factory = self.engine_factory or default_engine_factory()
        return factory()


def _pow2_at_most(n: int, cap: int) -> int:
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


class _ActiveGroup:
    """A DecodeGroup plus its engine state."""

    def __init__(self, group: DecodeGroup, state) -> None:
        self.group = group
        self.state = state
        self.t_decode0 = now()              # decode-span origin (tracing)


class ServeLoop:
    """The shard's serve loop, separable from the process for tests: it
    runs equally over a ``LocalTransport`` in a thread or a
    ``ProcTransport`` in a forked shard."""

    def __init__(self, transport: Transport, spec: ServeSpec, *,
                 engine=None, stop: Optional[threading.Event] = None,
                 identity: str = "infer-shard"):
        self.spec = spec
        self.identity = identity
        self.engine = engine if engine is not None else spec.make_engine()
        self.requests = transport.channel(spec.topic, "requests")
        self.results = transport.channel(spec.topic, "results")
        self.batcher = MicroBatcher(
            max_batch=spec.max_batch, prompt_buckets=spec.prompt_buckets,
            max_batch_delay=spec.max_batch_delay_ms / 1000.0)
        self.stop = stop if stop is not None else threading.Event()
        self.groups: List[_ActiveGroup] = []
        self.lease_timeout = getattr(transport, "lease_timeout", 30.0)
        # lease id -> requests of that drained batch still unpublished;
        # the heartbeat thread reads the keys, the serve loop writes --
        # the only shared state between the two threads
        self._lease_refs: dict = {}
        self._lease_lock = threading.Lock()
        self.stats = {"requests": 0, "published": 0, "claim_lost": 0,
                      "errors": 0, "prefills": 0, "decode_steps": 0,
                      "compactions": 0, "leases_acked": 0}

    # -- lease bookkeeping ---------------------------------------------------

    def _register_lease(self, lid: Optional[int], count: int) -> None:
        if lid is None:
            return
        if count <= 0:
            self.requests.ack_lease(lid)
            return
        with self._lease_lock:
            self._lease_refs[lid] = count

    def _release_lease(self, lid: Optional[int]) -> None:
        """One request of the lease reached its terminal publish; the
        lease commits when the last one does."""
        if lid is None:
            return
        last = False
        with self._lease_lock:
            n = self._lease_refs.get(lid)
            if n is None:
                return
            if n <= 1:
                del self._lease_refs[lid]
                last = True
            else:
                self._lease_refs[lid] = n - 1
        if last:
            self.requests.ack_lease(lid)
            self.stats["leases_acked"] += 1

    def _heartbeat(self, hb_stop: threading.Event) -> None:
        """Renew every held lease at half its timeout, like pool workers
        do for long tasks: a shard chewing through a deep queue must not
        have its undelivered requests redelivered out from under it."""
        interval = max(self.lease_timeout / 2.0, 0.05)
        while not hb_stop.wait(interval):
            with self._lease_lock:
                lids = list(self._lease_refs)
            for lid in lids:
                try:
                    self.requests.renew(lid)
                except (ConnectionError, OSError):
                    return              # fabric is gone; leases will expire

    # -- request intake ------------------------------------------------------

    def _decode_request(self, env: Envelope, lid: Optional[int]
                        ) -> Optional[InferenceRequest]:
        task: msg.Task = msg.deserialize(env.data)
        tokens = list(task.kwargs.get("tokens", ()))
        max_new = int(task.kwargs.get("max_new")
                      or self.spec.default_max_new)
        max_new = min(max_new, self.spec.max_new_cap)
        req = InferenceRequest(task_id=task.task_id, tokens=tokens,
                               max_new=max_new, enqueue_t=now(), lease=lid)
        if env.meta.get("trace"):
            # sampled at submit; the attempt number distinguishes the
            # sub-traces a lease-expiry redelivery produces
            req.meta["trace"] = 1
            req.meta["attempt"] = int(env.meta.get("redelivered", 0) or 0)
        if not tokens or len(tokens) > max(self.spec.prompt_buckets):
            self._publish_error(
                req, f"prompt length {len(tokens)} outside buckets "
                     f"{tuple(self.spec.prompt_buckets)}")
            return None
        return req

    def _intake(self) -> None:
        """Drain newly arrived requests into the batcher.  Blocks only
        when there is nothing to decode; with active groups it polls, so
        admission happens *between* decode steps."""
        room = (sum(len(a.group) for a in self.groups)
                + self.batcher.pending_count()) < 2 * self.spec.max_batch
        if self.groups:
            timeout = ADMIT_POLL if room else 0.0
        elif self.batcher.pending_count():
            deadline = self.batcher.next_deadline()
            timeout = max(deadline - now(), 0.0)
        else:
            timeout = None                  # idle: park until work arrives
        envs = self.requests.get_batch(self.spec.max_batch,
                                       timeout=timeout, cancel=self.stop)
        if not envs:
            return
        lid = self.requests.detach_lease()
        if any(e.meta.get("stop") for e in envs):
            # a shutdown marker: requeue any real requests that shared
            # its drain batch (verbatim, like the launcher's rescue) so
            # only the marker is consumed, then commit and exit
            for env in envs:
                if not env.meta.get("stop"):
                    self.requests.put(env)
            self.requests.ack_lease(lid, flush=True)
            self.stop.set()
            return
        count = 0
        for env in envs:
            req = self._decode_request(env, lid)
            if req is not None:
                self.batcher.add(req)
                count += 1
            self.stats["requests"] += 1
        self._register_lease_counted(lid, len(envs), count)

    def _register_lease_counted(self, lid: Optional[int], total: int,
                                queued: int) -> None:
        """Register the drained batch's lease for ``total`` envelopes;
        rejected requests already published their error result, so their
        share is released immediately."""
        self._register_lease(lid, total)
        for _ in range(total - queued):
            self._release_lease(lid)

    # -- serving -------------------------------------------------------------

    def _publish(self, req: InferenceRequest, value, *, success: bool,
                 error: Optional[str] = None) -> None:
        t_fin = now()
        result = msg.Result(task_id=req.task_id, topic=self.spec.topic,
                            method="infer", success=success, value=value,
                            error=error, worker=self.identity)
        data = msg.serialize(result)
        meta = {"output_size": len(data), "task_id": req.task_id}
        if req.meta.get("trace"):
            meta["trace"] = 1               # keep the result hop sampled
        won = self.results.put(Envelope(t_fin, data, meta),
                               claim=req.task_id)
        if req.meta.get("trace"):
            obs.span(req.task_id, "retire", t_fin, now(),
                     attempt=req.meta.get("attempt", 0), claimed=bool(won))
        self.stats["published" if won else "claim_lost"] += 1
        self._release_lease(req.lease)

    def _publish_error(self, req: InferenceRequest, error: str) -> None:
        self.stats["errors"] += 1
        self._publish(req, None, success=False, error=error)

    def _finish_rows(self, active: _ActiveGroup) -> None:
        """Stream out rows that reached their target, then shrink the
        engine state when the survivors fit a smaller batch bucket."""
        g = active.group
        done = g.finished()
        if not done:
            return
        t_fin = now()
        for req, toks in done:
            if req.meta.get("trace"):
                obs.span(req.task_id, "decode", active.t_decode0, t_fin,
                         attempt=req.meta.get("attempt", 0),
                         new_tokens=len(toks))
            self._publish(req, list(toks), success=True)
        g.retire_finished()
        target = g.compaction(active.state.padded_b)
        if target is not None:
            idx = list(g.slots)
            idx += [idx[0]] * (target - len(idx))
            active.state = self.engine.gather_rows(active.state, idx)
            g.reset_slots()
            self.stats["compactions"] += 1

    def _admit(self) -> None:
        """Prefill every micro-batch the batcher deems ready."""
        for mb in self.batcher.pop_ready(now()):
            t_admit = now()
            obs.observe("batch_occupancy",
                        len(mb.requests) / self.spec.max_batch)
            for req in mb.requests:
                obs.observe("infer_queue_delay", t_admit - req.enqueue_t)
                if req.meta.get("trace"):
                    obs.span(req.task_id, "infer_queue", req.enqueue_t,
                             t_admit, attempt=req.meta.get("attempt", 0),
                             bucket=mb.bucket)
            padded_b = batch_bucket(len(mb.requests), self.spec.max_batch)
            reserve = mb.bucket + _pow2_at_most(mb.max_new,
                                                self.spec.max_new_cap)
            try:
                first, state = self.engine.prefill_batch(
                    mb.padded_tokens(padded_b), reserve=reserve)
            except Exception as exc:        # noqa: BLE001
                for req in mb.requests:
                    self._publish_error(req, f"prefill failed: {exc!r}")
                continue
            self.stats["prefills"] += 1
            obs.counter("prefills").inc()
            t_prefilled = now()
            for req in mb.requests:
                if req.meta.get("trace"):
                    obs.span(req.task_id, "prefill", t_admit, t_prefilled,
                             attempt=req.meta.get("attempt", 0),
                             rows=len(mb.requests))
            active = _ActiveGroup(DecodeGroup(mb, first, self.spec.max_batch),
                                  state)
            active.t_decode0 = t_prefilled
            self._finish_rows(active)       # max_new == 1 rows
            if not active.group.done:
                self.groups.append(active)

    def _step(self) -> None:
        """One decode step per active group (round-robin), streaming out
        rows as they finish.  Returning to the caller between steps is
        what interleaves intake/admission with decode."""
        survivors = []
        for active in self.groups:
            try:
                nxt = self.engine.decode_batch(active.state)
            except Exception as exc:        # noqa: BLE001
                for req in active.group.rows:
                    self._publish_error(req, f"decode failed: {exc!r}")
                continue
            self.stats["decode_steps"] += 1
            obs.counter("decode_steps").inc()
            active.group.record_step(nxt)
            self._finish_rows(active)
            if not active.group.done:
                survivors.append(active)
        self.groups = survivors

    def run(self) -> None:
        hb_stop = threading.Event()
        hb = threading.Thread(target=self._heartbeat, args=(hb_stop,),
                              daemon=True,
                              name=f"infer-hb-{self.spec.topic}")
        hb.start()
        try:
            while not self.stop.is_set():
                self._intake()
                if self.stop.is_set():
                    break
                self._admit()
                self._step()
                obs.flush_metrics()         # throttled cumulative snapshot
        finally:
            hb_stop.set()
            hb.join(timeout=2)
            obs.flush_metrics(force=True)   # final cumulative snapshot
            try:
                self.results.ack(flush=True)    # flush piggybacked acks
            except (ConnectionError, OSError):
                pass


# -- process wrapper ---------------------------------------------------------

def inference_shard_main(address: tuple, spec: ServeSpec, *,
                         lease_timeout: float = 30.0,
                         identity: str = "infer-shard",
                         env: Optional[dict] = None) -> None:
    """Entry point of a forked shard process: dial the broker that homes
    the serve topic, build the engine (first jax import happens here,
    inside the child), serve until a stop envelope or SIGTERM.  ``env``
    entries (``ClusterSpec.env_for``) are applied before the engine
    build so XLA-style variables precede the first jax import."""
    from repro.core.transport.proc import ProcTransport

    if env:
        os.environ.update(env)
    stop = threading.Event()

    def _sigterm(signum, frame):
        stop.set()
        raise SystemExit(0)                 # unblocks a parked get_batch

    signal.signal(signal.SIGTERM, _sigterm)
    transport = ProcTransport(address=address, lease_timeout=lease_timeout)
    ref, offset = "", None
    if obs.enabled():
        try:
            offset = obs.calibrate(transport.clock_sync)
            ref = obs.addr_str(address)
        except (ConnectionError, OSError, RuntimeError, KeyError,
                TypeError, ValueError):
            offset = None                   # telemetry only: never fatal
    obs.configure(role="infer", ref=ref, offset=offset)
    loop = ServeLoop(transport, spec, stop=stop, identity=identity)
    try:
        loop.run()
    except SystemExit:
        pass
    os._exit(0)


def start_inference_shard(address: tuple, spec: ServeSpec, *,
                          lease_timeout: float = 30.0,
                          identity: str = "infer-shard",
                          env: Optional[dict] = None):
    """Fork one shard process against ``address`` (a broker reachable
    with the serve topic).  Used by the cluster launcher, the serving
    bench, and the chaos tests."""
    p = _mp.Process(target=inference_shard_main, args=(address, spec),
                    kwargs={"lease_timeout": lease_timeout,
                            "identity": identity, "env": env},
                    daemon=True, name=f"colmena-{identity}")
    p.start()
    return p


def send_shard_stop(transport: Transport, topic: str, n: int = 1) -> None:
    """Graceful shutdown: enqueue ``n`` stop markers on the serve topic
    (one per shard draining it)."""
    ch = transport.channel(topic, "requests")
    for _ in range(n):
        ch.put(Envelope(now(), b"", {"stop": True}))


class InferenceClient:
    """Client-side batching façade over ``ColmenaQueues``: splits a list
    of prompts into one request per prompt (the shard re-batches them by
    bucket -- possibly alongside other clients' traffic), then drains
    the serve topic's results and reassembles them in submission order.
    """

    def __init__(self, queues, *, topic: Optional[str] = None):
        self.queues = queues
        self.topic = topic or queues.serve_topic

    def submit(self, prompts: Sequence[Sequence[int]], *,
               max_new: Optional[int] = None) -> List[str]:
        return [self.queues.send_inference(list(p), max_new=max_new,
                                           topic=self.topic)
                for p in prompts]

    def gather(self, task_ids: Sequence[str], *,
               timeout: Optional[float] = None) -> List[msg.Result]:
        """Block until every id has a result; returns them in the order
        of ``task_ids`` regardless of completion order."""
        want = set(task_ids)
        got: dict = {}
        deadline = None if timeout is None else now() + timeout
        while want - set(got):
            remaining = None
            if deadline is not None:
                remaining = deadline - now()
                if remaining <= 0:
                    raise TimeoutError(
                        f"{len(want - set(got))} of {len(want)} inference"
                        " results still missing")
            for r in self.queues.get_results(self.topic, max_n=64,
                                             timeout=remaining):
                got[r.task_id] = r
        return [got[t] for t in task_ids]

    def infer(self, prompts: Sequence[Sequence[int]], *,
              max_new: Optional[int] = None,
              timeout: Optional[float] = None) -> List[msg.Result]:
        """Submit + gather: transparent split/reassemble."""
        return self.gather(self.submit(prompts, max_new=max_new),
                           timeout=timeout)
