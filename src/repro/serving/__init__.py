"""Model serving: the batched KV-cache engine and the inference-shard
fabric role.

Import submodules explicitly -- ``repro.serving.engine`` pulls in jax,
while ``repro.serving.shard`` / ``repro.serving.batcher`` are
deliberately jax-free at import time so fabric processes can declare a
``ServeSpec`` (or run the client side) without loading the accelerator
stack.  The shard process builds its engine lazily after the fork.
"""
