"""Serving driver: batched generation with the KV-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --batch 4 --prompt-len 64 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import api
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3,
                    help="number of batched request rounds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = Engine(cfg, params, max_new=args.max_new)

    rng = np.random.default_rng(args.seed)
    for r in range(args.requests):
        prompts = rng.integers(0, cfg.vocab_size,
                               size=(args.batch, args.prompt_len),
                               dtype=np.int32)
        out = engine.generate(prompts)
        print(f"round {r}: in {prompts.shape} -> out {out.shape}, "
              f"sample tail: {out[0, -8:].tolist()}")
    print(f"steady-state throughput: {engine.throughput():.1f} tok/s "
          f"(prefills={engine.stats['prefill_calls']}, "
          f"decode_steps={engine.stats['decode_steps']}, "
          f"compile {engine.stats['compile_wall']:.2f}s excluded)")


if __name__ == "__main__":
    main()
