"""Program builders: train_step / prefill / decode_step + their shardings.

This is the single source of truth the dry-run, the trainer, the serving
engine and the benchmarks all use.  For every (arch x input-shape) cell it
provides:

- ``input_specs(cfg, shape)``      : ShapeDtypeStruct stand-ins, no allocation
- ``input_shardings(cfg, shape, mesh)``
- ``abstract_state(cfg)`` / ``state_shardings(cfg, mesh, sc)``
- ``make_train_step(cfg, tc, sc)`` : grad accumulation, clip, AdamW, guards
- ``make_prefill(cfg)`` / ``make_decode_step(cfg)``
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, ShapeConfig, ShardingConfig,
                                TrainConfig)
from repro.distributed import axisenv, sharding as shd
from repro.models import api
from repro.optim import adamw, clip, schedules


def _with_axisenv(fn, mesh, global_batch, mode="dp_tp"):
    """Wrap a step fn so model-level sharding constraints resolve during
    tracing (axisenv is consulted at trace time)."""
    bax = shd.batch_axes(mesh, global_batch, mode)
    sizes = tuple(int(mesh.shape[a]) for a in bax)
    # in dp_only mode no tensor axis lives on "model"
    model = "model" if "model" in mesh.shape and mode != "dp_only" else None
    msize = int(mesh.shape.get("model", 1))

    def wrapped(*args):
        with axisenv.activation_axes(batch=bax, batch_sizes=sizes,
                                     model=model, model_size=msize,
                                     mesh=mesh):
            return fn(*args)
    return wrapped


# ---------------------------------------------------------------------------
# Cache logical axes (mirrors api.init_cache structure)
# ---------------------------------------------------------------------------

_KV_AXES = {"k": ("layer", "batch", "seq", "kv_heads", "head_dim"),
            "v": ("layer", "batch", "seq", "kv_heads", "head_dim")}


def cache_axes(cfg: ModelConfig):
    if cfg.is_encdec:
        return {"self": dict(_KV_AXES), "cross": dict(_KV_AXES)}
    if cfg.rwkv:
        return {
            "tm_shift": ("layer", "batch", "seq", "embed"),
            "cm_shift": ("layer", "batch", "seq", "embed"),
            "state": ("layer", "batch", "heads", "head_dim", "head_dim2"),
        }
    if cfg.family == "hybrid":
        return {
            "mamba": {
                "conv": ("layer", "batch", "conv", "ssm_inner"),
                "ssm": ("layer", "batch", "ssm_heads", "head_dim", "state"),
            },
            "attn": dict(_KV_AXES),
        }
    return dict(_KV_AXES)


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, B: int, S: int, *, with_labels: bool):
    """Abstract model-input batch for a full-sequence program."""
    cd = cfg.compute_dtype
    out = {}
    if cfg.family == "vlm":
        out["embeds"] = _sds((B, S, cfg.d_model), cd)
        out["positions"] = _sds((3, B, S), "int32")
    else:
        out["tokens"] = _sds((B, S), "int32")
    if cfg.is_encdec:
        out["frames"] = _sds((B, S, cfg.d_model), cd)
    if with_labels:
        out["labels"] = _sds((B, S), "int32")
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every program input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, B, S, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, B, S, with_labels=False)}
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: api.init_cache(cfg, B, S, enc_len=S))
        return {
            "cache": cache,
            "tokens": _sds((B, 1), "int32"),
            "cur_len": _sds((), "int32"),
        }
    raise ValueError(shape.kind)


def _batch_input_shardings(cfg, specs, mesh, global_batch, mode="dp_tp"):
    bax = shd.batch_axes(mesh, global_batch, mode)
    lead = bax if bax else None

    def spec_of(name, s):
        if name == "positions":
            return P(None, lead, None)
        return P(lead, *([None] * (len(s.shape) - 1)))

    return {name: NamedSharding(mesh, spec_of(name, s))
            for name, s in specs.items()}


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    mode: str = "dp_tp"):
    specs = input_specs(cfg, shape)
    if shape.kind in ("train", "prefill"):
        return {"batch": _batch_input_shardings(
            cfg, specs["batch"], mesh, shape.global_batch, mode)}
    # decode
    axes = cache_axes(cfg)
    cache_sh = jax.tree.map(
        lambda ax, s: NamedSharding(mesh, shd.cache_spec(
            ax, s.shape, mesh, shape.global_batch)),
        axes, specs["cache"],
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
    bax = shd.batch_axes(mesh, shape.global_batch, mode)
    lead = bax if bax else None
    return {
        "cache": cache_sh,
        "tokens": NamedSharding(mesh, P(lead, None)),
        "cur_len": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------


def abstract_state(cfg: ModelConfig):
    params = api.abstract_params(cfg)
    opt = jax.eval_shape(adamw.init, params)
    return {"params": params, "opt": opt}


def init_state(cfg: ModelConfig, key):
    params = api.init_params(cfg, key)
    return {"params": params, "opt": adamw.init(params)}


def state_shardings(cfg: ModelConfig, mesh,
                    sc: Optional[ShardingConfig] = None):
    sc = sc or ShardingConfig()
    abs_params = api.abstract_params(cfg)
    pspecs = shd.tree_specs(api.param_specs(cfg), abs_params, mesh, sc.mode)

    def moment_spec(ps, ap):
        return shd.zero_spec(ps, ap.shape, mesh) if sc.zero >= 1 else ps

    mspecs = jax.tree.map(moment_spec, pspecs, abs_params,
                          is_leaf=lambda x: isinstance(x, P))
    to_sh = lambda t: jax.tree.map(
        lambda p: NamedSharding(mesh, p), t,
        is_leaf=lambda x: isinstance(x, P))
    return {
        "params": to_sh(pspecs),
        "opt": adamw.AdamWState(step=NamedSharding(mesh, P()),
                                m=to_sh(mspecs), v=to_sh(mspecs)),
    }


# ---------------------------------------------------------------------------
# Programs
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    sc: Optional[ShardingConfig] = None):
    sc = sc or ShardingConfig()

    def grads_of(params, batch):
        def lf(p):
            return api.loss_fn(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(
            lf, has_aux=True)(params)
        metrics = {**metrics, "loss": loss}
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        if sc.microbatches > 1:
            k = sc.microbatches

            def resh(t):
                b = t.shape[0]
                assert b % k == 0, (b, k)
                return t.reshape((k, b // k) + t.shape[1:])

            # positions (3,B,S) carries batch on dim 1
            mb = {}
            for name, t in batch.items():
                if name == "positions":
                    b = t.shape[1]
                    mb[name] = jnp.moveaxis(
                        t.reshape((3, k, b // k) + t.shape[2:]), 1, 0)
                else:
                    mb[name] = resh(t)

            def acc_body(carry, microbatch):
                g_acc, m_acc = carry
                g, m = grads_of(params, microbatch)
                g_acc = jax.tree.map(lambda a, b: a + b / k, g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b / k, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"ce": 0.0, "aux": 0.0, "tokens": 0.0, "loss": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), mb)
        else:
            grads, metrics = grads_of(params, batch)

        grads, nonfinite = clip.zero_nonfinite(grads)
        grads, gnorm = clip.clip_by_global_norm(grads, tc.grad_clip)
        lr = schedules.warmup_cosine(
            state["opt"].step, lr=tc.lr, warmup_steps=tc.warmup_steps,
            total_steps=tc.total_steps)
        new_params, new_opt = adamw.update(grads, state["opt"], params,
                                           lr, tc)
        metrics = {**metrics, "grad_norm": gnorm, "lr": lr,
                   "skipped": nonfinite.astype(jnp.float32)}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill(cfg: ModelConfig):
    def prefill(params, batch):
        return api.prefill(params, cfg, batch)
    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens, cur_len):
        return api.decode_step(params, cfg, cache, tokens, cur_len)
    return decode_step


# ---------------------------------------------------------------------------
# Jitted + sharded program assembly (used by dryrun / trainer / engine)
# ---------------------------------------------------------------------------


def build_program(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                  tc: Optional[TrainConfig] = None,
                  sc: Optional[ShardingConfig] = None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    tc = tc or TrainConfig()
    sc = sc or ShardingConfig()
    specs = input_specs(cfg, shape)
    in_sh = input_shardings(cfg, shape, mesh, sc.mode)
    st_sh = state_shardings(cfg, mesh, sc)

    if shape.kind == "train":
        fn = _with_axisenv(make_train_step(cfg, tc, sc), mesh,
                           shape.global_batch, sc.mode)
        jfn = jax.jit(fn,
                      in_shardings=(st_sh, in_sh["batch"]),
                      out_shardings=(st_sh, None),
                      donate_argnums=(0,))
        args = (abstract_state(cfg), specs["batch"])
        return jfn, args

    if shape.kind == "prefill":
        fn = _with_axisenv(make_prefill(cfg), mesh, shape.global_batch,
                           sc.mode)
        jfn = jax.jit(fn,
                      in_shardings=(st_sh["params"], in_sh["batch"]),
                      out_shardings=None)
        args = (api.abstract_params(cfg), specs["batch"])
        return jfn, args

    if shape.kind == "decode":
        fn = _with_axisenv(make_decode_step(cfg), mesh, shape.global_batch,
                           sc.mode)
        jfn = jax.jit(fn,
                      in_shardings=(st_sh["params"], in_sh["cache"],
                                    in_sh["tokens"], in_sh["cur_len"]),
                      out_shardings=(None, in_sh["cache"]),
                      donate_argnums=(1,))
        args = (api.abstract_params(cfg), specs["cache"], specs["tokens"],
                specs["cur_len"])
        return jfn, args

    raise ValueError(shape.kind)
