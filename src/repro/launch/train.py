"""Training driver: any assigned arch, checkpoint/restart, metrics.

Runs on whatever mesh is ambient -- the CPU host mesh for examples/smoke
and the production meshes on a real pod (same code path as the dry-run's
train program).  Demonstrates the fault-tolerance loop: async checkpoints
every --ckpt-every steps, `--resume` restores the newest valid checkpoint
and the deterministic step-keyed data stream realigns automatically.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ShardingConfig, TrainConfig, get_config
from repro.data.loader import PrefetchLoader
from repro.data.tokens import make_batch
from repro.launch import steps


def train(arch: str, *, reduced: bool = True, steps_total: int = 50,
          batch: int = 8, seq: int = 128, lr: float = 3e-4,
          ckpt_dir: str = None, ckpt_every: int = 20, resume: bool = False,
          microbatches: int = 1, log_every: int = 10, seed: int = 0,
          stop_after: int = None, print_fn=print):
    """stop_after: interrupt the run after this step (fault-injection /
    resume tests) without changing the LR schedule, which is always derived
    from steps_total."""
    cfg = get_config(arch, reduced=reduced)
    tc = TrainConfig(lr=lr, warmup_steps=max(steps_total // 20, 1),
                     total_steps=steps_total, seed=seed)
    sc = ShardingConfig(microbatches=microbatches)

    state = steps.init_state(cfg, jax.random.PRNGKey(seed))
    start_step = 0
    manager = None
    if ckpt_dir:
        manager = CheckpointManager(ckpt_dir)
        if resume:
            s, restored = manager.restore(state)
            if s is not None:
                state, start_step = restored, s
                print_fn(f"resumed from checkpoint step {s}")

    step_fn = jax.jit(steps.make_train_step(cfg, tc, sc),
                      donate_argnums=(0,))

    def batch_fn(step):
        return make_batch(cfg, "train", batch, seq, step=step, seed=seed)

    loader = PrefetchLoader(batch_fn, start_step=start_step)
    losses = []
    stop_at = min(steps_total, stop_after) if stop_after else steps_total
    t0 = time.perf_counter()
    try:
        for step, host_batch in loader:
            if step >= stop_at:
                break
            jbatch = jax.tree.map(jax.numpy.asarray, host_batch)
            state, metrics = step_fn(state, jbatch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0 or step == steps_total - 1:
                dt = time.perf_counter() - t0
                print_fn(f"step {step:5d} loss {loss:8.4f} "
                         f"ce {float(metrics['ce']):8.4f} "
                         f"gnorm {float(metrics['grad_norm']):7.3f} "
                         f"lr {float(metrics['lr']):.2e} "
                         f"({dt:.1f}s)")
            if manager and ckpt_every and step and step % ckpt_every == 0:
                manager.save(step, state)
    finally:
        loader.close()
        if manager:
            manager.wait()
    if manager:
        manager.save(stop_at, state, blocking=True)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true",
                    help="full published config (default: reduced)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, losses = train(args.arch, reduced=not args.full,
                      steps_total=args.steps, batch=args.batch, seq=args.seq,
                      lr=args.lr, microbatches=args.microbatches,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      resume=args.resume, seed=args.seed)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
