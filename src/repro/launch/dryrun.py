import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import: jax locks the device
# count on first initialization.  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  2. assembles the jitted program with full in/out shardings
     (repro.launch.steps.build_program),
  3. ``.lower().compile()`` -- any sharding mismatch, OOM-at-compile or
     unsupported collective fails the cell,
  4. records memory_analysis / cost_analysis / parsed collective traffic /
     roofline terms to JSON for EXPERIMENTS.md and the roofline bench.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out benchmarks/results/dryrun
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import (ARCH_IDS, SHAPES, ShardingConfig, TrainConfig,
                                active_param_count, get_config, param_count,
                                shape_applicable)
from repro.launch import analytic, hlo_analysis, steps
from repro.launch.mesh import make_production_mesh, mesh_chips


def probe_configs(cfg):
    """Two shallow, fully-unrolled configs for cost extrapolation.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so a scanned layer stack under-reports FLOPs/bytes/collectives.
    Every cost component is linear in the scan step count (loop bodies are
    identical; stacked-parameter collectives scale linearly in size), so two
    unrolled probes give an exact extrapolation:
        cost(full) = cost(p1) + (steps_full - 1) * (cost(p2) - cost(p1)).
    """
    if cfg.is_encdec:
        assert cfg.encoder_layers == cfg.num_layers
        c1 = cfg.replace(num_layers=1, encoder_layers=1, scan_unroll=True)
        c2 = cfg.replace(num_layers=2, encoder_layers=2, scan_unroll=True)
        return c1, c2, cfg.num_layers
    if cfg.family == "hybrid":
        ae = max(cfg.attn_every, 1)
        groups, tail = divmod(cfg.num_layers, ae)
        c1 = cfg.replace(num_layers=ae + tail, scan_unroll=True)
        c2 = cfg.replace(num_layers=2 * ae + tail, scan_unroll=True)
        return c1, c2, groups
    per = cfg.local_global_period or 1
    c1 = cfg.replace(num_layers=per, scan_unroll=True)
    c2 = cfg.replace(num_layers=2 * per, scan_unroll=True)
    return c1, c2, cfg.num_layers // per


def _compile_cell(cfg, shape, mesh, sc):
    jfn, args = steps.build_program(cfg, shape, mesh, tc=TrainConfig(),
                                    sc=sc)
    t0 = time.time()
    lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _cost_of(compiled, chips):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = hlo_analysis.collective_stats(compiled.as_text(), chips)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             sc: ShardingConfig = None, save_hlo: bool = False,
             out_dir: str = None, probes: bool = True, cfg_overrides=None):
    """Lower+compile one cell; returns the result record (raises on failure).

    cfg_overrides: dict of ModelConfig fields for perf iterations
    (e.g. {"seq_parallel": True, "remat": "policy"})."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    sc = sc or default_sharding(cfg, shape_name)
    with mesh:
        compiled, t_lower, t_compile = _compile_cell(cfg, shape, mesh, sc)
        mem = compiled.memory_analysis()
        f_scan, b_scan, coll_scan = _cost_of(compiled, chips)
        hlo = compiled.as_text()

        if probes:
            c1, c2, steps_full = probe_configs(cfg)
            comp1, _, t_p1 = _compile_cell(c1, shape, mesh, sc)
            f1, b1, w1 = _cost_of(comp1, chips)
            comp2, _, t_p2 = _compile_cell(c2, shape, mesh, sc)
            f2, b2, w2 = _cost_of(comp2, chips)
            lin = lambda a, b: a + (steps_full - 1) * max(b - a, 0.0)
            flops_dev = lin(f1, f2)
            bytes_dev = lin(b1, b2)
            wire_dev = lin(w1.total_wire_bytes, w2.total_wire_bytes)
            coll_detail = {
                "probe1": w1.as_dict(), "probe2": w2.as_dict(),
                "steps_full": steps_full,
            }
        else:
            flops_dev, bytes_dev = f_scan, b_scan
            wire_dev = coll_scan.total_wire_bytes
            coll_detail = None

    coll = coll_scan
    mem_model = analytic.analytic_hbm_bytes(cfg, shape, mesh, sc)
    roof = hlo_analysis.roofline_terms(
        flops=flops_dev * chips, hbm_bytes=bytes_dev * chips,
        wire_bytes=wire_dev, chips=chips)
    # analytic memory term (fused-TPU traffic model; see launch/analytic.py)
    mem_term = mem_model["total"] / hlo_analysis.HBM_BW
    roof["memory_analytic_s"] = mem_term
    terms = {"compute": roof["compute_s"], "memory": mem_term,
             "collective": roof["collective_s"]}
    roof["dominant_analytic"] = max(terms, key=terms.get)
    roof["step_lower_bound_analytic_s"] = max(terms.values())

    n_total = param_count(cfg)
    n_active = active_param_count(cfg)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill")
              else shape.global_batch)          # decode: 1 new token/seq
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * n_active * tokens
    useful = model_flops / max(flops_dev * chips, 1.0)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "chips": chips,
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "sharding": {"mode": sc.mode, "zero": sc.zero,
                     "microbatches": sc.microbatches,
                     "remat": sc.remat_override or cfg.remat},
        "cfg_overrides": cfg_overrides or {},
        "params_total": n_total, "params_active": n_active,
        "tokens_per_step": tokens,
        "model_flops": model_flops,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "hlo_flops_per_dev_scan_raw": f_scan,
        "useful_flop_frac": useful,
        "collectives": coll.as_dict(),
        "collective_wire_bytes_per_dev": wire_dev,
        "collective_probe_detail": coll_detail,
        "analytic_hbm_bytes_per_dev": mem_model,
        "roofline": roof,
        "memory_analysis": _mem_dict(mem),
        "t_lower_s": t_lower, "t_compile_s": t_compile,
    }
    if save_hlo and out_dir:
        fn = os.path.join(out_dir, f"{arch}_{shape_name}_"
                          f"{'multi' if multi_pod else 'single'}.hlo.txt")
        with open(fn, "w") as f:
            f.write(hlo)
    return rec


def default_sharding(cfg, shape_name: str) -> ShardingConfig:
    """Per-cell default distribution config (the paper-faithful baseline
    uses plain DP+TP; big-model cells need FSDP to be honest about fit)."""
    if cfg.name.startswith("kimi") or cfg.name.startswith("qwen2-vl"):
        return ShardingConfig(mode="fsdp_tp", zero=1)
    return ShardingConfig(mode="dp_tp", zero=1)


def _mem_dict(mem):
    if mem is None:
        return None
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(mem, attr):
            try:
                out[attr] = int(getattr(mem, attr))
            except Exception:
                pass
    return out or str(mem)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override key=value (perf iterations)")
    ap.add_argument("--mode", default=None,
                    help="ShardingConfig mode override (dp_tp|fsdp_tp|dp_only)")
    ap.add_argument("--tag", default="",
                    help="suffix for output json files")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        key, val = kv.split("=", 1)
        if val.lower() in ("true", "false"):
            val = val.lower() == "true"
        elif val.lstrip("-").isdigit():
            val = int(val)
        overrides[key] = val

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape_name}_{'multi' if multi else 'single'}"
                if args.tag:
                    tag += "_" + args.tag
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[cached] {tag}")
                    continue
                try:
                    sc = None
                    if args.mode:
                        import dataclasses
                        sc = dataclasses.replace(
                            default_sharding(get_config(arch), shape_name),
                            mode=args.mode)
                    # probes (cost extrapolation) only on the single-pod
                    # mesh; the roofline table is single-pod by assignment
                    rec = run_cell(arch, shape_name, multi, sc=sc,
                                   save_hlo=args.save_hlo, out_dir=args.out,
                                   probes=not multi,
                                   cfg_overrides=overrides or None)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "fail", "error": repr(e),
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skip"
                n_fail += st == "fail"
                if st == "ok":
                    r = rec["roofline"]
                    print(f"[ok]   {tag}: dom={r['dominant_analytic']} "
                          f"comp={r['compute_s']:.4f}s "
                          f"mem={r['memory_analytic_s']:.4f}s "
                          f"(xla {r['memory_s']:.3f}s) "
                          f"coll={r['collective_s']:.4f}s "
                          f"useful={rec['useful_flop_frac']:.2f} "
                          f"(compile {rec['t_compile_s']:.0f}s)")
                elif st == "skip":
                    print(f"[skip] {tag}: {rec['reason']}")
                else:
                    print(f"[FAIL] {tag}: {rec['error']}")
    print(f"\ndry-run summary: ok={n_ok} skip={n_skip} fail={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
