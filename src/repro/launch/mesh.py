"""Production mesh definitions.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
and then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= int(v)
    return n
