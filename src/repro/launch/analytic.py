"""Analytic per-device HBM traffic model (TPU execution assumption).

XLA:CPU's HloCostAnalysis "bytes accessed" counts every unfused operand
access; on this host backend it over-reports HBM traffic by >10x vs a fused
TPU executable (attention/SSD/WKV internals that our Pallas kernels keep in
VMEM dominate the overcount).  This module computes a *minimum-traffic*
estimate from first principles:

- every fusion-boundary activation tensor is written once and read once,
- attention / SSD / WKV internals cost zero HBM traffic (kernel-fused),
- parameters are read once per forward (and once more for the remat
  re-forward), gradients and Adam moments read+written once,
- decode reads the whole KV-cache share + writes one slot.

Both this estimate and the raw XLA number are reported in the roofline
table; the *analytic* one drives bottleneck identification (EXPERIMENTS.md
documents the discrepancy).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, ShardingConfig
from repro.distributed import sharding as shd
from repro.launch import steps
from repro.models import api


def _bytes_per_device(abs_tree, shardings):
    """Sum of leaf bytes divided by each leaf's shard count."""
    total = 0.0
    for s, sh in zip(jax.tree.leaves(abs_tree), jax.tree.leaves(shardings)):
        n = int(np.prod(s.shape)) if s.shape else 1
        shards = 1
        spec = sh.spec
        for axis in spec:
            if axis is None:
                continue
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                shards *= sh.mesh.shape[a]
        total += n * np.dtype(s.dtype).itemsize / shards
    return total


def _act_elems_per_token(cfg: ModelConfig, tp: int) -> float:
    """Fusion-boundary activation elements per token per layer, already
    divided by the tensor-parallel degree where the tensor is TP-sharded."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qo, kv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    qo_tp = qo / tp if cfg.num_heads % tp == 0 else qo
    kv_tp = kv / tp if cfg.num_kv_heads % tp == 0 else kv

    if cfg.rwkv:
        f = cfg.d_ff / tp if cfg.d_ff % tp == 0 else cfg.d_ff
        return 7 * d + f
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * d
        di_tp = d_inner / tp if d_inner % tp == 0 else d_inner
        return 5.1 * di_tp + 2 * d
    f = cfg.d_ff / tp if cfg.d_ff % tp == 0 else cfg.d_ff
    if cfg.is_moe:
        k = cfg.num_experts_per_token
        ffn = 3 * k * f * cfg.capacity_factor + 2 * k * d
    else:
        ffn = 3 * f
    return 6 * d + 2 * qo_tp + 2 * kv_tp + ffn


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       sc: ShardingConfig) -> dict:
    """Per-device HBM bytes for one step of this cell's program."""
    tp = 1 if sc.mode == "dp_only" else shd.mesh_axis_size(mesh, "model")
    bax = shd.batch_axes(mesh, shape.global_batch, sc.mode)
    dp = 1
    for a in bax:
        dp *= mesh.shape[a]

    abs_params = api.abstract_params(cfg)
    p_shard = shd.tree_shardings(api.param_specs(cfg), abs_params, mesh,
                                 sc.mode)
    params_dev = _bytes_per_device(abs_params, p_shard)

    act_bpt = _act_elems_per_token(cfg, tp) * 2.0          # bf16
    layers = cfg.num_layers + cfg.encoder_layers
    vocab_tp = cfg.vocab_size / tp if cfg.vocab_size % tp == 0 \
        else cfg.vocab_size

    out = {}
    if shape.kind == "train":
        tokens_dev = shape.global_batch * shape.seq_len / dp
        # params: fwd read + remat re-read; grads r+w; m,v r+w (f32); p write
        remat_f = 2.0 if cfg.remat != "none" else 1.0
        opt_div = mesh.shape.get("data", 1) if sc.zero >= 1 else 1
        p_traffic = params_dev * (remat_f + 1 + 2)          # reads+gradsrw+pw
        o_traffic = _bytes_per_device(abs_params, p_shard) / 2 * 8 * 2 \
            / opt_div                                       # m+v f32 r+w
        act = tokens_dev * act_bpt * layers * 3.0           # fwd w+r, bwd, remat
        logits = tokens_dev * vocab_tp * 2 * 4.0            # fwd w+r, bwd w+r
        embed = tokens_dev * cfg.d_model * 2 * 4.0
        out["total"] = p_traffic + o_traffic + act + logits + embed
        out.update(params=p_traffic, opt=o_traffic, act=act, logits=logits)
    elif shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / dp
        cache_dev = _bytes_per_device(
            jax.eval_shape(lambda: api.init_cache(
                cfg, shape.global_batch, shape.seq_len,
                enc_len=shape.seq_len)),
            steps_cache_shardings(cfg, shape, mesh))
        act = tokens_dev * act_bpt * layers * 1.0           # fwd w+r only
        logits = shape.global_batch / dp * vocab_tp * 2 * 2
        out["total"] = params_dev + act + cache_dev + logits
        out.update(params=params_dev, act=act, cache=cache_dev)
    else:  # decode
        cache_dev = _bytes_per_device(
            jax.eval_shape(lambda: api.init_cache(
                cfg, shape.global_batch, shape.seq_len,
                enc_len=shape.seq_len)),
            steps_cache_shardings(cfg, shape, mesh))
        logits = shape.global_batch / dp * vocab_tp * 2 * 2
        out["total"] = params_dev + cache_dev + logits
        out.update(params=params_dev, cache=cache_dev)
    return out


def steps_cache_shardings(cfg, shape, mesh):
    from jax.sharding import NamedSharding
    axes = steps.cache_axes(cfg)
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len,
                               enc_len=shape.seq_len))
    return jax.tree.map(
        lambda ax, s: NamedSharding(mesh, shd.cache_spec(
            ax, s.shape, mesh, shape.global_batch)),
        axes, cache,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
