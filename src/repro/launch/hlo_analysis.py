"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but not collective
traffic, so we parse the optimized HLO text and sum the tensor sizes moved
by every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (including their async -start forms).

Roofline model (TPU v5e targets):
    compute    = HLO_FLOPs  / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes  / (chips * 819e9  B/s HBM)
    collective = wire_bytes / (chips * 50e9   B/s per ICI link)

wire_bytes uses standard ring-algorithm factors: all-reduce moves
2*(n-1)/n of the tensor per device, the others (n-1)/n.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link direction

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.12 = bf16[16,1024,512]{2,1,0} all-gather(...)
#       ROOT %r = (f32[2]{0}, f32[4,4]{1,0}) all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(?P<out>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        members = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(members))
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))    # [num_groups, group_size]
    return default


@dataclass
class CollectiveStats:
    # op -> [count, tensor_bytes (per-device payload), wire_bytes]
    per_op: dict = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(v[2] for v in self.per_op.values())

    @property
    def total_tensor_bytes(self) -> float:
        return sum(v[1] for v in self.per_op.values())

    def as_dict(self):
        return {k: {"count": v[0], "tensor_bytes": v[1], "wire_bytes": v[2]}
                for k, v in self.per_op.items()}


def collective_stats(hlo_text: str, total_devices: int) -> CollectiveStats:
    """Sum collective payloads over the module (per-device program)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("out"))
        n = _group_size(line, total_devices)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / max(n, 1) * out_bytes
        elif op == "all-gather":
            wire = (n - 1) / max(n, 1) * out_bytes      # output is gathered
        elif op == "reduce-scatter":
            wire = (n - 1) * out_bytes                  # output is the shard
        elif op == "all-to-all":
            wire = (n - 1) / max(n, 1) * out_bytes
        else:  # collective-permute
            wire = float(out_bytes)
        rec = stats.per_op.setdefault(op, [0, 0.0, 0.0])
        rec[0] += 1
        rec[1] += float(out_bytes)
        rec[2] += float(wire)
    return stats


def roofline_terms(*, flops: float, hbm_bytes: float, wire_bytes: float,
                   chips: int) -> dict:
    """Three roofline terms in seconds + the dominant bottleneck.

    flops / hbm_bytes are whole-program totals (cost_analysis of the
    per-device module scaled by chips); wire_bytes is per-device."""
    compute = flops / (chips * PEAK_FLOPS)
    memory = hbm_bytes / (chips * HBM_BW)
    collective = wire_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dominant.replace("_s", "")
    terms["step_lower_bound_s"] = bound
    return terms
