"""Checkpoint manager: async writes, rotation, resume.

Fault-tolerance posture for 1000+ node runs:
- writes happen on a background thread (training never blocks on disk),
- each checkpoint is atomic (store.save) and checksummed,
- `latest()` skips torn/corrupt checkpoints and falls back to older ones,
- rotation keeps the newest `keep` checkpoints.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Optional

import jax

from repro.checkpoint import store

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # -- write ------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        # materialize on host *now* so training can mutate state afterwards
        host_tree = jax.tree.map(
            lambda t: jax.device_get(t) if hasattr(t, "device") else t, tree)
        self.wait()

        def work():
            path = self._path(step)
            store.save(path, host_tree)
            self._rotate()

        t = threading.Thread(target=work, daemon=True)
        with self._lock:
            self._pending = t
        t.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        with self._lock:
            t = self._pending
        if t is not None:
            t.join()
            with self._lock:
                if self._pending is t:
                    self._pending = None

    # -- read -------------------------------------------------------------

    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and store.exists(os.path.join(self.directory, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like, step: Optional[int] = None):
        """Restore newest valid checkpoint (or `step`).  Returns
        (step, tree) or (None, None)."""
        candidates = ([step] if step is not None
                      else list(reversed(self.steps())))
        for s in candidates:
            path = self._path(s)
            try:
                return s, store.restore(path, like)
            except Exception:
                continue        # torn write -> fall back to older
        return None, None

    # -- internals ---------------------------------------------------------

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}")

    def _rotate(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._path(s), ignore_errors=True)
