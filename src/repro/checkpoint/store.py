"""Pytree <-> on-disk checkpoint shards.

Arrays are flattened with '/'-joined key paths and written as .npz shards
(one shard per call; large trees could be split, the format supports it).
A JSON manifest records tree structure, dtypes and a content checksum so a
torn write is detected at restore time (fault tolerance: a half-written
checkpoint is never silently loaded).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.utils.trees import tree_flatten_with_paths

MANIFEST = "manifest.json"
SHARD = "arrays.npz"

# dtypes numpy's npz cannot round-trip -> stored as raw byte views
_EXTENDED = {"bfloat16": ml_dtypes.bfloat16,
             "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
             "float8_e5m2": ml_dtypes.float8_e5m2}


def _to_storable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _EXTENDED:
        return arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str):
    if dtype_name in _EXTENDED:
        return arr.reshape(arr.shape[:-1] + (-1,)) \
                  .view(_EXTENDED[dtype_name]) \
                  .reshape(arr.shape[:-1])
    return arr


def _checksum(arrays: dict) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes()[:1 << 16])
    return h.hexdigest()


def save(path: str, tree) -> None:
    """Atomic checkpoint write (tmp dir + rename)."""
    flat = tree_flatten_with_paths(tree)
    dtypes = {k: str(np.asarray(v).dtype) for k, v in flat}
    arrays = {k: _to_storable(np.asarray(v)) for k, v in flat}
    manifest = {
        "keys": [k for k, _ in flat],
        "dtypes": dtypes,
        "checksum": _checksum(arrays),
    }
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".ckpt_tmp_")
    try:
        np.savez(os.path.join(tmp, SHARD), **arrays)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            import shutil
            shutil.rmtree(path)
        os.rename(tmp, path)
    except BaseException:
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def restore(path: str, like):
    """Restore into the structure of `like` (values replaced by stored
    arrays, cast to the stored dtype).  Raises on checksum mismatch."""
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, SHARD)) as z:
        arrays = {k: z[k] for k in manifest["keys"]}
    if _checksum(arrays) != manifest["checksum"]:
        raise IOError(f"checkpoint {path} failed checksum (torn write?)")
    flat_like = tree_flatten_with_paths(like)
    leaves = []
    for key, ref in flat_like:
        arr = _from_storable(arrays[key], manifest["dtypes"][key])
        leaves.append(jnp.asarray(arr))
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves)


def exists(path: str) -> bool:
    return (os.path.isdir(path)
            and os.path.exists(os.path.join(path, MANIFEST))
            and os.path.exists(os.path.join(path, SHARD)))
