from repro.checkpoint import manager, store  # noqa: F401
