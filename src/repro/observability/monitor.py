"""CampaignMonitor: live fabric stats aggregation for the launcher.

Dials every federation member's broker with the idempotent
``stats_scrape`` op (each member answers for itself -- unknown ops fall
through the relay to the local broker, which is exactly the per-member
semantics a scrape wants) and optionally the Value Server's client-side
stats, and appends one merged snapshot line per tick to
``stats-monitor.jsonl`` in the observability directory.  The forked
roles' own sinks carry their cumulative metrics (tracer
``flush_metrics``); the monitor adds the *broker-side* view -- queue
depths, in-flight leases, expiry/claim-reject counters, live shm
segments -- which no consumer process can see.

Deliberately not imported by ``repro.observability.__init__``: this
module imports the transport layer (FrameClient), and the instrumented
transport imports the observability package -- keeping the aggregator
out of the package root keeps that edge one-way.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Callable, Dict, Optional

from repro.core.transport import frames
from repro.utils.timing import now


def scrape_address(address) -> dict:
    """One member's ``stats_scrape`` reply (``{}`` on a dead broker --
    a scrape must never take the campaign down with it)."""
    try:
        client = frames.FrameClient(tuple(address))
        try:
            header, _ = client.request({"op": "stats_scrape"}, retry=True)
            return header.get("stats", {}) or {}
        finally:
            client.close()
    except (ConnectionError, OSError, RuntimeError):
        return {}


class CampaignMonitor:
    """Periodic scraper over the federation's broker addresses.

    ``addresses``: ``{host_name: (host, port)}``;  ``vs_stats``: an
    optional zero-arg callable returning Value-Server stats to fold into
    each snapshot (e.g. ``ShardedValueServer.client_stats``).
    """

    def __init__(self, addresses: Dict[str, tuple], obs_dir: str,
                 interval: float = 2.0,
                 vs_stats: Optional[Callable[[], dict]] = None):
        self.addresses = dict(addresses)
        self.obs_dir = obs_dir
        self.interval = interval
        self.vs_stats = vs_stats
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.last: dict = {}

    # -- scraping ------------------------------------------------------

    def scrape(self) -> dict:
        snap: dict = {"t": now(), "brokers": {}}
        for name, addr in self.addresses.items():
            snap["brokers"][name] = scrape_address(addr)
        if self.vs_stats is not None:
            try:
                snap["value_server"] = self.vs_stats()
            except (ConnectionError, OSError, RuntimeError, KeyError):
                snap["value_server"] = {}
        self.last = snap
        return snap

    def _write(self, snap: dict) -> None:
        if not self.obs_dir:
            return
        path = os.path.join(self.obs_dir, "stats-monitor.jsonl")
        line = (json.dumps(snap, sort_keys=True, default=str)
                + "\n").encode()
        fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, line)
        finally:
            os.close(fd)

    def tick(self) -> dict:
        snap = self.scrape()
        self._write(snap)
        return snap

    # -- lifecycle -----------------------------------------------------

    def start(self) -> "CampaignMonitor":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name="campaign-monitor")
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.tick()
            except Exception:               # noqa: BLE001 -- telemetry
                pass                        # must never kill the fabric

    def stop(self, final_scrape: bool = True) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if final_scrape:
            try:
                self.tick()
            except Exception:               # noqa: BLE001
                pass
