"""Lock-light metrics registry embedded in every fabric role.

Counters, gauges and power-of-two-bucket histograms held in a plain
per-process dict.  Deliberately **lock-free**: all mutation is single
bytecode-level dict/int operations that the GIL serializes, the worst
race outcome is one lost increment (a telemetry rounding error, never a
correctness one), and -- decisive for this fabric -- no new locks means
no new edges in the lock-order witness graph for instrumented hot
paths to trip over.

The registry is per-process and fork-aware: a forked child starts from
its parent's counts unless it resets, which would double-count on
merge, so the registry self-clears on pid change (the
``_after_fork`` pid-check idiom).  Values leave the process either via
``snapshot()`` embedded in a ``stats_scrape`` reply (live processes) or
via the tracer's throttled ``flush_metrics`` jsonl lines (cumulative,
so SIGKILL costs at most the last unflushed window).
"""
from __future__ import annotations

import os
from typing import Dict


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Pow2-bucketed distribution: bucket ``b`` counts observations in
    ``[2^(b-21), 2^(b-20))`` -- micro-resolution near zero (bucket 0 is
    everything below ~1e-6), decades of headroom above, and integer-only
    bookkeeping on the observe path."""

    __slots__ = ("count", "sum", "buckets")

    def __init__(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.buckets: Dict[int, int] = {}

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        b = int(v * (1 << 20)).bit_length() if v > 0 else 0
        self.buckets[b] = self.buckets.get(b, 0) + 1


_registry: Dict[str, object] = {}
_registry_pid = -1


def _reg() -> Dict[str, object]:
    global _registry_pid
    pid = os.getpid()
    if pid != _registry_pid:
        # forked child: inherited counts belong to the parent's story
        _registry.clear()
        _registry_pid = pid
    return _registry


def counter(name: str) -> Counter:
    reg = _reg()
    c = reg.get(name)
    if type(c) is not Counter:
        c = reg.setdefault(name, Counter())   # racing threads converge
    return c                                   # type: ignore[return-value]


def gauge(name: str) -> Gauge:
    reg = _reg()
    g = reg.get(name)
    if type(g) is not Gauge:
        g = reg.setdefault(name, Gauge())
    return g                                   # type: ignore[return-value]


def histo(name: str) -> Histogram:
    reg = _reg()
    h = reg.get(name)
    if type(h) is not Histogram:
        h = reg.setdefault(name, Histogram())
    return h                                   # type: ignore[return-value]


def observe(name: str, v: float) -> None:
    histo(name).observe(v)


def snapshot() -> dict:
    """Primitive-only cumulative snapshot, safe to embed in a frame
    header reply or a jsonl line."""
    counters, gauges, histos = {}, {}, {}
    for name, obj in list(_reg().items()):
        if isinstance(obj, Counter):
            counters[name] = obj.value
        elif isinstance(obj, Gauge):
            gauges[name] = obj.value
        elif isinstance(obj, Histogram):
            histos[name] = {"count": obj.count, "sum": obj.sum,
                            "buckets": {str(k): v
                                        for k, v in obj.buckets.items()}}
    return {"counters": counters, "gauges": gauges, "histos": histos}


def reset() -> None:
    """Test hook: drop every instrument in this process."""
    _reg().clear()
