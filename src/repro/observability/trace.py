"""Per-process trace sinks: causal task spans on one fabric timeline.

Every fabric process (Thinker, broker, pool worker, inference shard)
appends span records for *sampled* tasks to its own
``spans-<host>-<role>-<pid>.jsonl`` file under ``REPRO_OBS_DIR`` --
the proven lock-witness sink pattern: each ``O_APPEND`` write is one
whole batch of newline-terminated records, atomic at the file offset
and durable past ``os._exit``/SIGKILL.  Records are *buffered* and
flushed in batches (``FLUSH_RECORDS`` records or ``FLUSH_SECONDS``,
whichever first): per-record writes on a journaling filesystem cost
tens of microseconds each and dominated the traced dispatch floor.
A daemon flusher thread drains the buffer every ``FLUSH_SECONDS`` *off
the task path* (an extending append on a journaling fs costs ~200us
under multi-process contention -- measured dominating the traced
dispatch floor when instants wrote through inline), so crash-evidence
records like the ``task_started`` instant are on disk within one flush
period of being emitted: a SIGKILLed attempt loses at most the last
``FLUSH_SECONDS`` of records, and anything older -- including the
instant that opened the attempt, for any execution longer than the
period -- survives.  Forced final metrics snapshots (process-exit
paths) still write through.  The report
(``repro.observability.report``) merges the sinks into one
Chrome-trace-event timeline.

Design constraints, in order:

- **The untraced hot path pays nothing.**  The sampling decision is
  made once per task at ``send_task`` (deterministic hash of the
  task_id against ``REPRO_OBS_SAMPLE``) and rides the envelope meta as
  ``meta["trace"] = 1``; every downstream hop emits spans only under
  that flag, so with tracing off (no ``REPRO_OBS_DIR``) zero span calls
  happen per task.
- **Fork-safe by pid check.**  The module singleton re-reads its
  environment and drops any inherited sink fd whenever ``os.getpid()``
  changes (the ``ProcTransport._after_fork`` idiom) -- forked brokers,
  workers and shards each get their own sink file.
- **Lock-free.**  No locks anywhere: the GIL makes the benign races
  harmless (two threads racing the sink-fd open end up with two fds on
  one O_APPEND file; a flush snapshots the buffer with an atomic list
  swap, so a concurrent append lands in the next batch -- or, in a
  pathological interleaving, drops one *sampled telemetry* record),
  and the lock-order witness sees no new edges.

Clock model: all span times are the emitting process's
``timing.now()`` (``perf_counter`` = CLOCK_MONOTONIC, which is
system-wide on Linux -- every process on one machine shares the
timebase).  For cross-machine alignment each process calibrates an
offset to its reference broker via the idempotent ``clock_sync`` op
(min-RTT midpoint over a few roundtrips) and records ``(ref, offset)``
in its sink's ``proc`` header line; member brokers calibrate against
the federation coordinator, so the report can compose offset chains
with the coordinator as the root of the shared timeline.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
import zlib
from typing import Callable, Optional

from repro.observability import metrics as _metrics
from repro.utils.timing import now

ENV_DIR = "REPRO_OBS_DIR"
ENV_SAMPLE = "REPRO_OBS_SAMPLE"
ENV_HOST = "REPRO_OBS_HOST"

#: sampling rate used when tracing is enabled without an explicit rate
DEFAULT_SAMPLE = 0.1

#: batch-flush thresholds for buffered sink records: the flusher thread
#: drains every FLUSH_SECONDS; a full buffer flushes inline as backstop
FLUSH_RECORDS = 256
FLUSH_SECONDS = 0.1


class _Tracer:
    """Module singleton; all state re-derived per pid (fork safety)."""

    def __init__(self) -> None:
        self._pid = -1
        self.dir = ""
        self.sample = DEFAULT_SAMPLE
        self.host = "local"
        self.role = "app"
        self.addr = ""                  # this process's service address
        self.ref = ""                   # clock reference (broker address)
        self.offset = 0.0               # + offset maps local t -> ref t
        self._sink_fd = -1
        self._wrote_head = False
        self._last_metrics_flush = 0.0
        self._buf: list = []
        self._last_write = 0.0
        self._flusher: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------

    def _ensure(self) -> None:
        pid = os.getpid()
        if pid == self._pid:
            return
        # fresh process (first call or just forked): env is the config
        # channel across fork/exec; an inherited fd points at the
        # parent's sink and must be dropped, not closed (the parent
        # still owns it) -- and inherited buffered records belong to
        # the parent (it will flush them itself) and must be dropped
        self._pid = pid
        self._sink_fd = -1
        self._wrote_head = False
        self._last_metrics_flush = 0.0
        self._buf = []
        self._last_write = now()
        self._flusher = None            # a thread never survives fork
        self.dir = os.environ.get(ENV_DIR, "")
        if self.dir:
            # normal process exit (atexit does not run under os._exit;
            # those paths -- pool workers, shards -- force-flush
            # explicitly) drains the buffered tail
            atexit.register(flush)
        try:
            self.sample = float(
                os.environ.get(ENV_SAMPLE, "") or DEFAULT_SAMPLE)
        except ValueError:
            self.sample = DEFAULT_SAMPLE
        self.host = os.environ.get(ENV_HOST, "") or self.host or "local"
        self.addr = ""
        self.ref = ""
        self.offset = 0.0

    @property
    def enabled(self) -> bool:
        return bool(self.dir)

    def _sink_path(self) -> str:
        safe_role = self.role.replace("/", "_").replace(":", "_")
        safe_host = self.host.replace("/", "_").replace(":", "_")
        return os.path.join(
            self.dir, f"spans-{safe_host}-{safe_role}-{self._pid}.jsonl")

    def _emit(self, rec: dict, through: bool = False) -> None:
        # record dicts buffer raw; json encoding happens at flush time
        # in the flusher thread -- measured, the per-record encode on a
        # GIL-saturated thinker/broker cost more dispatch-floor wall
        # than the disk writes themselves
        self._buf.append(rec)
        if self._flusher is None:
            self._start_flusher()
        if through or len(self._buf) >= FLUSH_RECORDS:
            self.flush()

    def _start_flusher(self) -> None:
        pid = self._pid

        def loop() -> None:
            while True:
                time.sleep(FLUSH_SECONDS)
                if os.getpid() != pid:      # belt and braces vs fork
                    return
                try:
                    self.flush()
                except OSError:             # sink dir torn down under us
                    return

        th = threading.Thread(target=loop, daemon=True, name="obs-flusher")
        self._flusher = th
        th.start()

    def flush(self) -> None:
        buf, self._buf = self._buf, []      # atomic swap (GIL): lock-free
        self._last_write = now()
        if not buf:
            return
        if self._sink_fd < 0:
            os.makedirs(self.dir, exist_ok=True)
            self._sink_fd = os.open(
                self._sink_path(),
                os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        # one O_APPEND write per batch: atomic at the offset, and ~batch
        # size fewer journal commits than per-record writes
        os.write(self._sink_fd, ("\n".join(
            json.dumps(r, sort_keys=True) for r in buf) + "\n").encode())

    def _head(self) -> None:
        if self._wrote_head:
            return
        self._wrote_head = True
        self._emit({"kind": "proc", "host": self.host, "role": self.role,
                    "pid": self._pid, "addr": self.addr, "ref": self.ref,
                    "offset": self.offset, "t": now()})


_T = _Tracer()


# -----------------------------------------------------------------------
# module API (what instrumented fabric code calls)
# -----------------------------------------------------------------------


def enabled() -> bool:
    _T._ensure()
    return _T.enabled


def sample_rate() -> float:
    _T._ensure()
    return _T.sample


def obs_dir() -> str:
    _T._ensure()
    return _T.dir


def configure(role: Optional[str] = None, host: Optional[str] = None,
              addr: str = "", ref: str = "",
              offset: Optional[float] = None) -> None:
    """Identify this process on the fabric timeline.  Called once from
    each role's process main (after any env the launcher pushed has been
    applied); writes the sink's ``proc`` header line eagerly so every
    participating process is visible to the report even if it ends up
    emitting no sampled spans."""
    _T._ensure()
    if role is not None:
        _T.role = role
    if host is not None:
        _T.host = host
    if addr:
        _T.addr = addr
    if ref:
        _T.ref = ref
    if offset is not None:
        _T.offset = offset
    if _T.enabled:
        _T._head()


def sampled(trace_id: str) -> bool:
    """Deterministic per-task sampling decision: every hop that hashes
    the same id agrees, with no coordination."""
    _T._ensure()
    if not _T.dir:
        return False
    if _T.sample >= 1.0:
        return True
    if _T.sample <= 0.0:
        return False
    return (zlib.crc32(trace_id.encode()) % 10_000) < _T.sample * 10_000


def span(trace_id: str, name: str, t0: float, t1: float,
         attempt: int = 0, **args) -> None:
    """One completed interval of a sampled task's lifecycle.  Times are
    this process's local monotonic clock; the report aligns them via the
    proc-header offset."""
    _T._ensure()
    if not _T.dir:
        return
    _T._head()
    rec = {"kind": "span", "trace": trace_id, "name": name,
           "t0": t0, "t1": t1}
    if attempt:
        rec["attempt"] = attempt
    if args:
        rec["args"] = args
    _T._emit(rec)


def instant(trace_id: str, name: str, t: Optional[float] = None,
            attempt: int = 0, **args) -> None:
    """A zero-duration marker.  The flusher thread puts it on disk
    within ``FLUSH_SECONDS`` -- so for any execution longer than that,
    the ``task_started`` instant of a SIGKILLed attempt survives as the
    crash evidence: an instant with no closing span."""
    _T._ensure()
    if not _T.dir:
        return
    _T._head()
    rec = {"kind": "instant", "trace": trace_id, "name": name,
           "t": now() if t is None else t}
    if attempt:
        rec["attempt"] = attempt
    if args:
        rec["args"] = args
    _T._emit(rec)


def emit_timers(trace_id: str, intervals: dict) -> None:
    """The envelope Timer's final interval set for a sampled task, as
    seen by the result consumer.  The report checks the merged span
    decomposition sums against these totals (the acceptance bound)."""
    _T._ensure()
    if not _T.dir:
        return
    _T._head()
    _T._emit({"kind": "timers", "trace": trace_id,
              "intervals": {k: float(v) for k, v in intervals.items()}})


def flush_metrics(min_interval: float = 0.5, force: bool = False) -> None:
    """Append a cumulative metrics snapshot line, throttled.  Snapshots
    are cumulative, so losing the final window to SIGKILL costs only
    that window's delta -- everything flushed earlier is on disk."""
    _T._ensure()
    if not _T.dir:
        return
    t = now()
    if not force and t - _T._last_metrics_flush < min_interval:
        return
    _T._last_metrics_flush = t
    snap = _metrics.snapshot()
    if not any(snap.values()) and not force:
        return
    _T._head()
    # force is the process-exit path: write through so the final
    # cumulative snapshot (and any buffered span tail) reaches disk
    _T._emit({"kind": "metrics", "t": t, "data": snap}, through=force)


def flush() -> None:
    """Drain buffered sink records to disk (no-op when untraced).
    Called from fabric teardown paths -- ``ColmenaQueues.shutdown``,
    broker exit -- and registered via ``atexit`` for normal exits."""
    _T._ensure()
    if _T.dir:
        _T.flush()


def addr_str(address) -> str:
    """Canonical string form of a broker address, used for ``addr``/
    ``ref`` in proc headers so the report can match reference chains:
    a Unix socket is its path, TCP is ``host:port``."""
    if isinstance(address, bytes):
        return address.decode(errors="replace")
    if isinstance(address, str):
        return address
    try:
        if address and address[0] == "unix":
            return str(address[1])
        return f"{address[0]}:{address[1]}"
    except (TypeError, IndexError):
        return str(address)


def calibrate(sync_fn: Callable[[], float], rounds: int = 5) -> float:
    """Estimate this process's clock offset to a reference: ``sync_fn``
    performs one ``clock_sync`` roundtrip and returns the reference's
    ``now()``.  Min-RTT midpoint over ``rounds`` tries -- the shortest
    roundtrip has the least asymmetric queueing, so its midpoint is the
    best bound on where the remote read actually happened."""
    best_rtt = float("inf")
    offset = 0.0
    for _ in range(rounds):
        a = now()
        t_ref = sync_fn()
        b = now()
        rtt = b - a
        if rtt < best_rtt:
            best_rtt = rtt
            offset = t_ref - (a + rtt / 2.0)
    return offset
