"""Fabric-wide observability: causal task traces, role metrics, and the
campaign monitor/report that merge them into one timeline.

Three layers (see the module docstrings for the full contracts):

- ``trace`` -- per-process O_APPEND jsonl span sinks; sampling decided
  once per task at submit and carried as envelope meta; clock offsets
  calibrated via the idempotent ``clock_sync`` broker op.
- ``metrics`` -- lock-free per-process counters/gauges/histograms,
  scraped live via the ``stats_scrape`` broker op or flushed to the
  span sinks.
- ``monitor`` / ``report`` -- the launcher-side aggregator and the
  ``python -m repro.observability.report`` exporter (Chrome-trace JSON
  for Perfetto + the paper's Fig.-5 decomposition table).

Instrumented fabric code imports this package as ``obs`` by
convention::

    from repro import observability as obs

    if env.meta.get("trace"):
        obs.span(task_id, "queue_wait", t_put, now(), topic=topic)
    obs.counter("expired_leases").inc()

The ``obs.span(...)``/``obs.counter(...)`` receiver-name convention is
what the ``span-name-registry`` fabriclint pass keys on: every name
literal at such a call site in ``core/**``/``serving/**`` must be
declared in ``observability.names``.
"""
from repro.observability.metrics import (counter, gauge, histo, observe,
                                         snapshot as metrics_snapshot)
from repro.observability.names import METRIC_NAMES, SPAN_NAMES
from repro.observability.trace import (DEFAULT_SAMPLE, ENV_DIR, ENV_HOST,
                                       ENV_SAMPLE, addr_str, calibrate,
                                       configure, emit_timers, enabled,
                                       flush, flush_metrics, instant,
                                       obs_dir, sample_rate, sampled, span)

__all__ = [
    "METRIC_NAMES", "SPAN_NAMES", "DEFAULT_SAMPLE",
    "ENV_DIR", "ENV_HOST", "ENV_SAMPLE",
    "addr_str", "calibrate", "configure", "counter", "emit_timers",
    "enabled", "flush", "flush_metrics", "gauge", "histo", "instant",
    "metrics_snapshot", "obs_dir", "observe", "sample_rate", "sampled",
    "span",
]
