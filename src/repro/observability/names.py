"""Single-source registry of every span and metric name the fabric emits.

Dashboards, the report's Fig.-5 decomposition table, and the chaos
trace-continuity tests all key on these names.  Instrumentation in
``core/**`` and ``serving/**`` may only use names declared here -- the
``span-name-registry`` fabriclint pass enforces it (the same
single-source pattern as ``IDEMPOTENT_OPS``), so a renamed span cannot
silently drop out of a dashboard or acceptance check.

Span names mirror the ``Timer`` interval names wherever both exist
(``serialize_request``, ``execute``, ...): the span is emitted at the
same moment, from the same measurement, as the ``timer.record`` call --
which is what makes the report's per-task span decomposition sum to the
envelope Timer totals by construction rather than by luck.
"""

# span name -> one-line description (who emits it, what it bounds)
SPAN_NAMES = {
    # -- task lifecycle (mirrors Timer intervals where named alike) ------
    "submit": "Thinker: send_task entry to transport put return",
    "serialize_request": "Thinker: task payload pickle",
    "shm_write": "producer: payload copy into a /dev/shm segment",
    "queue_wait": "broker: envelope enqueue (t_put) to lease grant",
    "shm_read": "consumer: payload map+copy out of a /dev/shm segment",
    "request_queue_transit": "worker: envelope t_put to decode "
                             "(sender/receiver clocks; same machine "
                             "shares CLOCK_MONOTONIC)",
    "deserialize_request": "worker: task payload unpickle",
    "task_started": "worker: instant marker written BEFORE execute -- a "
                    "SIGKILLed attempt leaves this and nothing after it",
    "execute": "worker: user function wall time",
    "serialize_result": "worker: result payload pickle",
    "publish_result": "worker: fused put+claim of the result envelope",
    "result_queue_transit": "Thinker: result envelope t_put to decode",
    "deserialize_result": "Thinker: result payload unpickle",
    # -- inference shard lifecycle ---------------------------------------
    "infer_queue": "shard: request enqueue to micro-batch admission",
    "prefill": "shard: the admitted group's prefill call",
    "decode": "shard: first decode step to the row's finish",
    "retire": "shard: row finish to result publish",
    # -- streaming steering (per-observation, under the task's trace) ----
    "report_intermediate": "worker: observation serialize + stream "
                           "publish (one span per observation)",
    "observation_transit": "Thinker: observation envelope t_put to decode",
}

# metric name -> one-line description (role, kind)
METRIC_NAMES = {
    # -- broker (counters live; depth/lease gauges computed at scrape) ---
    "expired_leases": "broker counter: leases that hit their deadline",
    "redeliveries": "broker counter: envelopes requeued by lease expiry",
    "claim_rejects": "broker counter: fused put+claim lost the claim race",
    "backup_clones": "broker counter: straggler backup clones enqueued",
    "queue_depth": "broker gauge (scrape-computed): queued envelopes/topic",
    "inflight_leases": "broker gauge (scrape-computed): leased envelopes",
    "shm_segments": "broker gauge (scrape-computed): live shm segments",
    # -- pool workers ----------------------------------------------------
    "tasks_completed": "worker counter: results published",
    "task_retries": "worker counter: failed attempts requeued for retry",
    "worker_busy_frac": "worker gauge: execute wall / process uptime",
    # -- inference shards ------------------------------------------------
    "prefills": "shard counter: micro-batch prefill calls",
    "decode_steps": "shard counter: decode steps across all groups",
    "batch_occupancy": "shard histogram: admitted rows / max_batch",
    "infer_queue_delay": "shard histogram: request enqueue-to-admission (s)",
    # -- streaming steering / preemption ---------------------------------
    "tasks_cancelled": "broker counter: cancel ops that won the claim "
                       "(lease revoked, queued copies destroyed)",
    "cancel_latency": "Thinker histogram: cancel() call to broker "
                      "revocation acknowledged (s)",
    "observations": "worker counter: intermediate observations published",
    "observations_dropped": "worker counter: observations dropped because "
                            "the task was already cancelled",
}

__all__ = ["SPAN_NAMES", "METRIC_NAMES"]
