"""Merge per-process span sinks into one campaign timeline.

``python -m repro.observability.report OBS_DIR --out trace.json`` emits
a Chrome-trace-event JSON file (load it at https://ui.perfetto.dev or
chrome://tracing) where every process is a named track and every
sampled task is one ``tid`` row of its causal spans across Thinker,
broker, worker and shard processes.  ``--table`` prints the paper's
Fig.-5-style per-span decomposition (count/median/p90/total) plus any
scraped role metrics; ``--check-decomposition R`` exits nonzero unless
the merged span sums agree with the envelope Timer totals within
ratio ``R`` (the PR's acceptance bound).

Clock alignment: each sink's ``proc`` header carries ``(ref, offset)``
from ``clock_sync`` calibration -- offset maps that process's local
monotonic times onto its reference broker's clock, and member brokers
carry their own offset to the federation coordinator.  Offsets compose
along that (depth <= 2) chain, with the coordinator the root of the
shared timeline.  On one machine CLOCK_MONOTONIC is already
system-wide, so offsets are microseconds; the chain exists for the
cross-machine case.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

# span names whose measurements mirror Timer intervals: the
# decomposition check compares exactly these against the timers records
TIMER_MIRRORED = ("serialize_request", "request_queue_transit",
                  "deserialize_request", "execute", "serialize_result",
                  "result_queue_transit", "deserialize_result")


def read_sinks(obs_dir) -> Tuple[List[dict], List[dict], List[dict],
                                 List[dict]]:
    """Returns (procs, spans, timers, metrics); span/instant records are
    annotated with their emitting proc's host/role/pid.  A truncated
    final line (a writer killed mid-write; O_APPEND makes this the only
    corruption mode) is skipped, not fatal."""
    procs: List[dict] = []
    spans: List[dict] = []
    timers: List[dict] = []
    metrics: List[dict] = []
    for path in sorted(Path(obs_dir).glob("spans-*.jsonl")):
        proc: Optional[dict] = None
        for line in path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            kind = rec.get("kind")
            if kind == "proc":
                proc = rec
                procs.append(rec)
                continue
            if proc is not None:
                rec.setdefault("host", proc["host"])
                rec.setdefault("role", proc["role"])
                rec.setdefault("pid", proc["pid"])
            if kind in ("span", "instant"):
                spans.append(rec)
            elif kind == "timers":
                timers.append(rec)
            elif kind == "metrics":
                rec["_path"] = path.name
                metrics.append(rec)
    return procs, spans, timers, metrics


def global_offsets(procs: List[dict]) -> Dict[Tuple[str, str, int], float]:
    """(host, role, pid) -> offset onto the coordinator's clock.  A
    process's header offset maps it onto its ref broker; if that broker
    itself declares a ref (member -> coordinator), the offsets add."""
    by_addr: Dict[str, dict] = {}
    for p in procs:
        if p.get("addr"):
            by_addr[str(p["addr"])] = p
    out: Dict[Tuple[str, str, int], float] = {}
    for p in procs:
        off = float(p.get("offset", 0.0))
        ref = str(p.get("ref", "") or "")
        hops = 0
        while ref and hops < 4:                 # chain depth is <= 2 today
            parent = by_addr.get(ref)
            if parent is None or parent is p:
                break
            off += float(parent.get("offset", 0.0))
            ref = str(parent.get("ref", "") or "")
            hops += 1
        out[(p["host"], p["role"], p["pid"])] = off
    return out


def _aligned(rec: dict, offsets) -> Tuple[float, float]:
    off = offsets.get((rec.get("host"), rec.get("role"), rec.get("pid")),
                      0.0)
    if rec.get("kind") == "instant":
        t = float(rec["t"]) + off
        return t, t
    return float(rec["t0"]) + off, float(rec["t1"]) + off


def to_chrome(procs: List[dict], spans: List[dict]) -> dict:
    """Chrome trace-event JSON: one pid per fabric process (named
    ``host/role/pid``), one tid row per sampled task so its lifecycle
    reads left-to-right across process tracks."""
    offsets = global_offsets(procs)
    pids: Dict[Tuple[str, str, int], int] = {}
    events: List[dict] = []
    for p in procs:
        key = (p["host"], p["role"], p["pid"])
        if key in pids:
            continue
        pids[key] = len(pids) + 1
        events.append({"name": "process_name", "ph": "M", "pid": pids[key],
                       "tid": 0, "args": {"name": "/".join(
                           str(k) for k in key)}})
    tids: Dict[str, int] = {}
    t_zero = None
    aligned = []
    for rec in spans:
        t0, t1 = _aligned(rec, offsets)
        aligned.append((t0, t1, rec))
        if t_zero is None or t0 < t_zero:
            t_zero = t0
    for t0, t1, rec in aligned:
        key = (rec.get("host"), rec.get("role"), rec.get("pid"))
        pid = pids.setdefault(key, len(pids) + 1)
        trace = str(rec.get("trace", "?"))
        tid = tids.setdefault(trace, len(tids) + 1)
        args = {"trace": trace, "attempt": rec.get("attempt", 0)}
        args.update(rec.get("args") or {})
        ev = {"name": rec["name"], "cat": rec.get("role", "fabric"),
              "pid": pid, "tid": tid,
              "ts": (t0 - (t_zero or 0.0)) * 1e6, "args": args}
        if rec.get("kind") == "instant":
            ev.update(ph="i", s="t")
        else:
            ev.update(ph="X", dur=max(t1 - t0, 0.0) * 1e6)
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _percentile(xs: List[float], q: float) -> float:
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, int(q * (len(xs) - 1) + 0.5))
    return xs[i]


def decomposition_table(spans: List[dict]) -> List[tuple]:
    """(name, count, median_ms, p90_ms, total_s) per span name -- the
    Fig.-5 per-component overhead decomposition, from merged spans."""
    by_name: Dict[str, List[float]] = {}
    for rec in spans:
        if rec.get("kind") != "span":
            continue
        by_name.setdefault(rec["name"], []).append(
            float(rec["t1"]) - float(rec["t0"]))
    rows = []
    for name in sorted(by_name):
        ds = by_name[name]
        rows.append((name, len(ds), _percentile(ds, 0.5) * 1e3,
                     _percentile(ds, 0.9) * 1e3, sum(ds)))
    return rows


def check_decomposition(spans: List[dict], timers: List[dict],
                        max_drift: float = 0.1) -> Tuple[int, int, float]:
    """Per sampled task: sum of Timer-mirrored span durations vs the sum
    of the envelope Timer's matching intervals.  Spans are emitted from
    the same measurements as ``timer.record``, so agreement is
    structural; drift beyond ``max_drift`` means an instrumentation hop
    dropped or double-emitted a span.  Returns (checked, failed,
    worst_drift); traces with under 10 ms of accounted time are skipped
    (relative drift on microsecond sums is noise, not signal)."""
    span_sum: Dict[str, float] = {}
    for rec in spans:
        if rec.get("kind") == "span" and rec["name"] in TIMER_MIRRORED:
            span_sum[str(rec["trace"])] = (
                span_sum.get(str(rec["trace"]), 0.0)
                + float(rec["t1"]) - float(rec["t0"]))
    checked = failed = 0
    worst = 0.0
    for rec in timers:
        trace = str(rec["trace"])
        want = sum(float(v) for k, v in rec["intervals"].items()
                   if k in TIMER_MIRRORED)
        got = span_sum.get(trace)
        if got is None or want < 0.010:
            continue
        checked += 1
        drift = abs(got - want) / want
        worst = max(worst, drift)
        if drift > max_drift:
            failed += 1
    return checked, failed, worst


def summarize_metrics(metrics: List[dict]) -> Dict[str, dict]:
    """Last cumulative snapshot per sink file, merged: counters sum
    across processes, gauges report the last value per process."""
    last: Dict[str, dict] = {}
    for rec in metrics:
        last[rec["_path"]] = rec            # jsonl order = time order
    counters: Dict[str, int] = {}
    gauges: Dict[str, list] = {}
    for rec in last.values():
        data = rec.get("data", {})
        for k, v in data.get("counters", {}).items():
            counters[k] = counters.get(k, 0) + v
        for k, v in data.get("gauges", {}).items():
            gauges.setdefault(k, []).append(v)
    return {"counters": counters, "gauges": gauges}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability.report",
        description="merge span sinks; export a Perfetto-loadable "
                    "Chrome-trace timeline and the Fig.-5 table")
    ap.add_argument("obs_dir", type=Path, help="REPRO_OBS_DIR of the run")
    ap.add_argument("--out", type=Path, default=None,
                    help="write Chrome trace-event JSON here")
    ap.add_argument("--table", action="store_true",
                    help="print the per-span decomposition table")
    ap.add_argument("--check-decomposition", type=float, default=None,
                    metavar="R", help="fail if any task's span sum "
                    "drifts more than R from its Timer totals")
    args = ap.parse_args(argv)

    procs, spans, timers, metrics = read_sinks(args.obs_dir)
    hosts = sorted({p["host"] for p in procs})
    roles = sorted({p["role"] for p in procs})
    n_traces = len({str(r.get("trace")) for r in spans})
    print(f"{len(procs)} process(es) on {len(hosts)} host(s) "
          f"{hosts}, roles {roles}; {len(spans)} span/instant record(s) "
          f"across {n_traces} sampled task(s)")

    if args.out is not None:
        args.out.write_text(json.dumps(to_chrome(procs, spans)))
        print(f"wrote {args.out} ({args.out.stat().st_size} bytes) -- "
              "load it at https://ui.perfetto.dev")

    if args.table:
        rows = decomposition_table(spans)
        if rows:
            w = max(len(r[0]) for r in rows)
            print(f"\n{'span':<{w}}  {'count':>6}  {'median':>9}  "
                  f"{'p90':>9}  {'total':>9}")
            for name, n, med, p90, tot in rows:
                print(f"{name:<{w}}  {n:>6}  {med:>7.3f}ms  "
                      f"{p90:>7.3f}ms  {tot:>8.3f}s")
        summary = summarize_metrics(metrics)
        if summary["counters"]:
            print("\ncounters (summed across processes):")
            for k, v in sorted(summary["counters"].items()):
                print(f"  {k}: {v}")
        for k, vs in sorted(summary["gauges"].items()):
            print(f"  {k}: {['%.3g' % v for v in vs]}")

    if args.check_decomposition is not None:
        checked, failed, worst = check_decomposition(
            spans, timers, args.check_decomposition)
        print(f"\ndecomposition check: {checked} task(s) checked, "
              f"{failed} beyond {args.check_decomposition:.0%} drift "
              f"(worst {worst:.1%})")
        if checked == 0:
            print("decomposition check: no checkable tasks "
                  "(need sampled tasks with >=10ms accounted time)")
            return 1
        if failed:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
