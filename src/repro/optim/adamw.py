"""AdamW with f32 moments over arbitrary pytrees (no optax dependency).

The moment tensors reuse each parameter's logical sharding; with
``zero >= 1`` the train-step builder additionally shards them over the
"data" mesh axis (see repro.distributed.sharding.zero_spec).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: dict                  # f32 pytree like params
    v: dict                  # f32 pytree like params


def init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=zeros(params), v=zeros(params))


def update(grads, state: AdamWState, params, lr, tc: TrainConfig):
    """Returns (new_params, new_state). lr is a scalar (already scheduled).
    Weight decay is decoupled and applied to matrix-like params only."""
    step = state.step + 1
    b1, b2 = tc.b1, tc.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + tc.eps)
        if p.ndim >= 2 and tc.weight_decay:
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
