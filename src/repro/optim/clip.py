"""Global-norm gradient clipping + non-finite guard."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.trees import tree_global_norm


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, pre_clip_norm)."""
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def zero_nonfinite(grads):
    """Replace non-finite gradient leaves with zeros (skip-step guard);
    returns (grads, any_nonfinite flag)."""
    flags = [jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)]
    ok = jnp.all(jnp.stack(flags)) if flags else jnp.asarray(True)
    grads = jax.tree.map(
        lambda g: jnp.where(ok, g, jnp.zeros_like(g)), grads)
    return grads, ~ok
