from repro.optim import adamw, clip, compress, schedules  # noqa: F401
