"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, lr, warmup_steps, total_steps, final_frac=0.1):
    step = step.astype(jnp.float32)
    warm = lr * step / jnp.maximum(warmup_steps, 1)
    t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, lr, **_):
    del step
    return jnp.asarray(lr, jnp.float32)
