"""Gradient compression for the slow cross-pod hop.

On a multi-pod mesh the gradient all-reduce decomposes into a fast
intra-pod reduce-scatter + a slow inter-pod exchange over DCI links.  We
compress only the inter-pod hop:

- ``bf16``: cast the shard to bf16 before the cross-pod psum (2x bytes).
- ``int8_ef``: per-tensor-scaled int8 quantization with error feedback
  (the residual is carried in the optimizer state and added to the next
  step's gradient, so the quantization error does not accumulate).

These run inside a shard_map over the "pod" axis (see launch/train.py's
manual-reduce mode); the quantization math itself is mesh-agnostic and
unit-tested directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, method: str, errors=None):
    """Quantize a gradient pytree; returns (payload, new_errors).

    payload leaves are (q, scale) for int8_ef, bf16 arrays for bf16.
    errors is the error-feedback state (same tree as grads, f32).
    """
    if method == "none":
        return grads, errors
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), errors
    if method == "int8_ef":
        if errors is None:
            errors = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q, s = quantize_int8(corrected)
            new_e = corrected - dequantize_int8(q, s)
            return (q, s), new_e

        pairs = jax.tree.map(one, grads, errors)
        payload = jax.tree.map(lambda t: t[0], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_errors = jax.tree.map(lambda t: t[1], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))
        return payload, new_errors
    raise ValueError(method)


def decompress_tree(payload, method: str, like=None):
    if method == "none":
        return payload
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), payload)
    if method == "int8_ef":
        return jax.tree.map(
            lambda qs: dequantize_int8(*qs), payload,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    raise ValueError(method)


def psum_compressed(grads, axis_name: str, method: str, errors=None):
    """Cross-axis gradient mean with compression (for use inside shard_map).

    int8_ef sums by all-gathering the int8 shards + dequantized local sum,
    which halves the bytes on the wire vs a bf16 all-reduce."""
    n = jax.lax.psum(1, axis_name)
    if method == "none":
        return jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads), errors
    if method == "bf16":
        summed = jax.tree.map(
            lambda g: jax.lax.pmean(g.astype(jnp.bfloat16), axis_name)
            .astype(g.dtype), grads)
        return summed, errors
    if method == "int8_ef":
        payload, new_errors = compress_tree(grads, method, errors)

        def reduce_one(qs):
            q, s = qs
            qg = jax.lax.all_gather(q, axis_name)        # (n, ...) int8
            sg = jax.lax.all_gather(s, axis_name)        # (n,) f32
            vals = qg.astype(jnp.float32) * sg.reshape(
                (-1,) + (1,) * q.ndim)
            return jnp.mean(vals, axis=0)

        summed = jax.tree.map(
            reduce_one, payload,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
        return summed, new_errors
    raise ValueError(method)
