"""Task-lifecycle instrumentation (paper §III-C).

Every Colmena message carries a ``Timer`` that records wall-clock intervals for
each stage of the task lifecycle: serialization, queue transit, dispatch,
execution, result serialization, result transit.  The paper measures exactly
these components (Fig. 5); we reproduce the measurement machinery so Thinker
policies can reason about overheads at plan time.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field


def now() -> float:
    return time.perf_counter()


@dataclass
class Timer:
    """Accumulates named wall-clock intervals for one task's lifecycle."""

    intervals: dict = field(default_factory=dict)
    marks: dict = field(default_factory=dict)

    def mark(self, name: str) -> None:
        self.marks[name] = now()

    def record(self, name: str, seconds: float) -> None:
        self.intervals[name] = self.intervals.get(name, 0.0) + seconds

    def span(self, name: str, start_mark: str, end_mark: str) -> None:
        if start_mark in self.marks and end_mark in self.marks:
            self.record(name, self.marks[end_mark] - self.marks[start_mark])

    @contextmanager
    def time(self, name: str):
        t0 = now()
        try:
            yield
        finally:
            self.record(name, now() - t0)

    def total(self, *names: str) -> float:
        return sum(self.intervals.get(n, 0.0) for n in names)

    def as_dict(self) -> dict:
        return dict(self.intervals)


class RateMeter:
    """Utilization / throughput meter over a sliding campaign window.

    Cumulative totals (``busy``, ``utilization``) cover the whole
    campaign; the per-event record is bounded to the last
    ``window_events`` entries (the fabric's sliding-window idiom, cf.
    ``BoundedIdSet``) -- a million-task campaign keeps a million-task
    utilization number without a million-entry list.
    """

    def __init__(self, window_events: int = 4096):
        self.busy = 0.0
        self.count = 0
        self.start = now()
        self.events = deque(maxlen=window_events)  # (t, kind, seconds)

    def add_busy(self, seconds: float, kind: str = "task") -> None:
        self.busy += seconds
        self.count += 1
        self.events.append((now() - self.start, kind, seconds))

    def utilization(self, capacity: float) -> float:
        """busy_time / (capacity * elapsed); capacity in worker-slots."""
        elapsed = max(now() - self.start, 1e-9)
        return self.busy / (capacity * elapsed)

    def recent_rate(self) -> float:
        """Events/second over the retained window (0.0 until two
        events exist)."""
        if len(self.events) < 2:
            return 0.0
        dt = self.events[-1][0] - self.events[0][0]
        return (len(self.events) - 1) / max(dt, 1e-9)
