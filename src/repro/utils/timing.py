"""Task-lifecycle instrumentation (paper §III-C).

Every Colmena message carries a ``Timer`` that records wall-clock intervals for
each stage of the task lifecycle: serialization, queue transit, dispatch,
execution, result serialization, result transit.  The paper measures exactly
these components (Fig. 5); we reproduce the measurement machinery so Thinker
policies can reason about overheads at plan time.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field


def now() -> float:
    return time.perf_counter()


@dataclass
class Timer:
    """Accumulates named wall-clock intervals for one task's lifecycle."""

    intervals: dict = field(default_factory=dict)
    marks: dict = field(default_factory=dict)

    def mark(self, name: str) -> None:
        self.marks[name] = now()

    def record(self, name: str, seconds: float) -> None:
        self.intervals[name] = self.intervals.get(name, 0.0) + seconds

    def span(self, name: str, start_mark: str, end_mark: str) -> None:
        if start_mark in self.marks and end_mark in self.marks:
            self.record(name, self.marks[end_mark] - self.marks[start_mark])

    @contextmanager
    def time(self, name: str):
        t0 = now()
        try:
            yield
        finally:
            self.record(name, now() - t0)

    def total(self, *names: str) -> float:
        return sum(self.intervals.get(n, 0.0) for n in names)

    def as_dict(self) -> dict:
        return dict(self.intervals)


class RateMeter:
    """Utilization / throughput meter over a sliding campaign window."""

    def __init__(self):
        self.busy = 0.0
        self.start = now()
        self.events = []  # (t, kind, payload)

    def add_busy(self, seconds: float, kind: str = "task") -> None:
        self.busy += seconds
        self.events.append((now() - self.start, kind, seconds))

    def utilization(self, capacity: float) -> float:
        """busy_time / (capacity * elapsed); capacity in worker-slots."""
        elapsed = max(now() - self.start, 1e-9)
        return self.busy / (capacity * elapsed)
