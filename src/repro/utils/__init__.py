from repro.utils import timing, trees
