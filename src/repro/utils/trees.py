"""Pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree) -> int:
    """Total bytes of all array leaves in a pytree."""
    return sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree.leaves(tree)
        if hasattr(leaf, "dtype")
    )


def tree_count_params(tree) -> int:
    """Total element count of all array leaves."""
    return sum(np.prod(leaf.shape, dtype=np.int64) if leaf.shape else 1
               for leaf in jax.tree.leaves(tree) if hasattr(leaf, "shape"))


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(
        lambda leaf: jnp.zeros(leaf.shape, dtype or leaf.dtype), tree
    )


def tree_cast(tree, dtype):
    return jax.tree.map(lambda leaf: leaf.astype(dtype), tree)


def tree_finite(tree) -> jax.Array:
    """True iff every leaf is finite everywhere."""
    leaves = [jnp.all(jnp.isfinite(leaf)) for leaf in jax.tree.leaves(tree)]
    return jnp.all(jnp.stack(leaves)) if leaves else jnp.asarray(True)


def tree_global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(leaf.astype(jnp.float32)))
              for leaf in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.asarray(0.0)


def tree_flatten_with_paths(tree):
    """[(path_string, leaf)] for every leaf, '/'-joined dict keys."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out
