"""SynApp (paper §IV-D1): measure Colmena overheads for your own
{T, D, I, O, N} configuration -- the paper publishes this exact tool for
assessing whether Colmena fits a use case.

    PYTHONPATH=src python examples/synapp_envelope.py --T 100 --D 0.01 \
        --I 1048576 --O 0 --N 8 [--no-value-server]
"""
import argparse

from repro.apps.synapp import SynConfig, run_synapp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--T", type=int, default=100)
    ap.add_argument("--D", type=float, default=0.01)
    ap.add_argument("--I", type=int, default=1 << 20)
    ap.add_argument("--O", type=int, default=0)
    ap.add_argument("--N", type=int, default=8)
    ap.add_argument("--no-value-server", action="store_true")
    args = ap.parse_args()

    res = run_synapp(SynConfig(T=args.T, D=args.D, I=args.I, O=args.O,
                               N=args.N,
                               use_value_server=not args.no_value_server))
    print(f"completed {res['n_results']} tasks in {res['makespan']:.2f}s")
    print(f"utilization: {100*res['utilization']:.1f}%")
    print("median lifecycle components (us):")
    for k, v in sorted(res["medians"].items()):
        print(f"  {k:28s} {v*1e6:10.1f}")
    print(f"total overhead (median): "
          f"{res['total_overhead_median']*1e6:.1f} us/task")


if __name__ == "__main__":
    main()
