"""Quickstart: the three layers of the framework in one script.

1. Colmena steering (the paper's Listing 1 policy) on toy tasks.
2. Train a reduced LM architecture for a few steps.
3. Serve it with the batched KV-cache engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ColmenaQueues, TaskServer
from repro.core.thinker import BaseThinker, agent, result_processor


def colmena_demo():
    print("== 1. Colmena steering (paper Listing 1) ==")
    TOTAL, PAR = 10, 3
    queues = ColmenaQueues(["simulate"])
    server = TaskServer(queues, workers_per_topic=PAR)
    server.register(lambda x: x ** 2, name="simulate")

    class Thinker(BaseThinker):
        def __init__(self, q):
            super().__init__(q)
            self.results = []

        @agent
        def planner(self):
            for i in range(PAR):
                self.queues.send_task(float(i), method="simulate",
                                      topic="simulate")

        @result_processor(topic="simulate")
        def consumer(self, result):
            self.results.append(result.value)
            if len(self.results) >= TOTAL:
                self.done.set()
            else:
                # steer: next input = sqrt of the best seen so far
                best = max(self.results)
                self.queues.send_task(best ** 0.5, method="simulate",
                                      topic="simulate")

    t = Thinker(queues)
    with server:
        t.run(timeout=30)
    print(f"   completed {len(t.results)} steered tasks; "
          f"best={max(t.results):.2f}\n")


def train_demo():
    print("== 2. Train a reduced qwen3-8b for 20 steps ==")
    from repro.launch.train import train
    _, losses = train("qwen3-8b", reduced=True, steps_total=20, batch=4,
                      seq=64, log_every=5)
    print(f"   loss {np.mean(losses[:3]):.3f} -> {np.mean(losses[-3:]):.3f}\n")


def serve_demo():
    print("== 3. Serve with the KV-cache engine ==")
    import jax
    from repro.configs.base import get_config
    from repro.models import api
    from repro.serving.engine import Engine
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new=8)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(2, 16), dtype=np.int32)
    eng.generate(prompts)                   # warmup: compiles the bucket
    out = eng.generate(prompts)
    print(f"   generated {out.shape} ({eng.throughput():.0f} tok/s "
          "steady-state)\n")


if __name__ == "__main__":
    colmena_demo()
    train_demo()
    serve_demo()
    print("quickstart OK")
