"""End-to-end driver: the paper's electrolyte-design campaign (§II-B/§IV).

ML-steered search for high-ionization-potential molecules: MPNN-ensemble
surrogate (JAX) + synthetic QC oracle, orchestrated by the Colmena
Thinker/Task Server with UCB steering and periodic retraining.  Compares
the paper's three policies and prints a Fig. 3-style utilization trace
with --trace.

    PYTHONPATH=src python examples/electrolyte_design.py \
        --molecules 800 --budget 60 [--policy all] [--trace]
"""
import argparse

from repro.apps.electrolyte import AppConfig, run_campaign


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--molecules", type=int, default=800)
    ap.add_argument("--budget", type=int, default=60)
    ap.add_argument("--initial-train", type=int, default=48)
    ap.add_argument("--n-retrain", type=int, default=12)
    ap.add_argument("--policy", default="all",
                    choices=["all", "random", "no-retrain", "update-n"])
    ap.add_argument("--trace", action="store_true",
                    help="print the campaign event trace (Fig. 3-style)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    policies = (["random", "no-retrain", "update-n"]
                if args.policy == "all" else [args.policy])
    outs = {}
    for policy in policies:
        outs[policy] = run_campaign(
            AppConfig(num_molecules=args.molecules, qc_budget=args.budget,
                      initial_train=args.initial_train,
                      n_retrain=args.n_retrain, policy=policy,
                      seed=args.seed),
            verbose=True)
        if args.trace:
            print(f"--- {policy} trace ---")
            for t, kind, payload in outs[policy]["trace"][:50]:
                print(f"  t={t:7.2f}s {kind:8s} {payload}")

    if len(outs) == 3:
        rnd = max(outs["random"]["success_rate"], 1e-4)
        print(f"\nsteered/random discovery advantage: "
              f"{outs['update-n']['success_rate'] / rnd:.0f}x "
              f"(paper: ~100x at scale)")


if __name__ == "__main__":
    main()
