"""Train a ~100M-parameter LM for a few hundred steps (deliverable (b)).

The config is a scaled llama-family model (~129M params incl. embeddings).
On CPU this runs at a few steps/min; pass --steps to go longer on real
hardware.  Demonstrates checkpoint/restart: interrupt and re-run with
--resume.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig  # noqa: E402
import repro.configs as _configs_pkg  # noqa: E402,F401


CONFIG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    num_layers=10,
    d_model=640,
    num_heads=10,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=50_000,
    head_dim=64,
    rope_theta=10_000.0,
    act="silu",
    remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # register the config so launch.train can find it
    import types
    mod = types.ModuleType("repro.configs.repro_100m")
    mod.CONFIG = CONFIG_100M
    mod.reduced = lambda: CONFIG_100M
    sys.modules["repro.configs.repro_100m"] = mod
    from repro.configs import base
    if "repro-100m" not in base.ARCH_IDS:
        base.ARCH_IDS.append("repro-100m")

    from repro.configs.base import param_count
    print(f"repro-100m: {param_count(CONFIG_100M)/1e6:.0f}M params")

    from repro.launch.train import train
    _, losses = train("repro-100m", reduced=False, steps_total=args.steps,
                      batch=args.batch, seq=args.seq, lr=6e-4,
                      ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      resume=args.resume, log_every=10)
    print(f"loss: {np.mean(losses[:5]):.4f} -> {np.mean(losses[-5:]):.4f}")


if __name__ == "__main__":
    main()
