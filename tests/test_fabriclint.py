"""fabriclint: each pass individually, the bad-code fixtures through the
real CLI, the pragma escapes, the baseline ratchet, and the repo itself
staying clean."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import fabriclint as FL
from repro.analysis.idempotent_ops import IDEMPOTENT_OPS

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "fabriclint"


def lint_source(src: str, pass_name: str, rel: str = "core/x.py"):
    ctx = FL.FileCtx(Path("<mem>"), rel, src)
    return [f for f in FL.PASSES[pass_name](ctx)
            if not ctx.suppressed(pass_name, f.line)]


# ---------------------------------------------------------------------------
# one pass at a time
# ---------------------------------------------------------------------------


class TestWaitNeedsPredicate:
    GOOD_WHILE = """
import threading
cond = threading.Condition()
def consume(items):
    with cond:
        while not items:
            cond.wait()
"""
    GOOD_TIMEOUT = """
import threading
cond = threading.Condition()
def tick(interval):
    with cond:
        cond.wait(interval)
"""
    BAD = """
import threading
cond = threading.Condition()
def consume(items):
    with cond:
        if not items:
            cond.wait()
"""

    def test_while_loop_ok(self):
        assert lint_source(self.GOOD_WHILE, "wait-needs-predicate") == []

    def test_timeout_bound_ok(self):
        assert lint_source(self.GOOD_TIMEOUT, "wait-needs-predicate") == []

    def test_bare_wait_flagged(self):
        fs = lint_source(self.BAD, "wait-needs-predicate")
        assert len(fs) == 1 and fs[0].line == 7

    def test_event_wait_not_flagged(self):
        src = """
import threading
stop = threading.Event()
def loop():
    stop.wait()
"""
        assert lint_source(src, "wait-needs-predicate") == []

    def test_while_in_outer_function_does_not_count(self):
        src = """
import threading
cond = threading.Condition()
def outer(items):
    while True:
        def inner():
            with cond:
                cond.wait()
        inner()
"""
        fs = lint_source(src, "wait-needs-predicate")
        assert len(fs) == 1


class TestIdempotentRetryRegistry:
    def test_registered_op_ok(self):
        src = 'def f(c):\n    c.request({"op": "snapshot"}, retry=True)\n'
        assert lint_source(src, "idempotent-retry-registry") == []

    def test_unregistered_op_flagged(self):
        src = 'def f(c):\n    c.request({"op": "put"}, retry=True)\n'
        fs = lint_source(src, "idempotent-retry-registry")
        assert len(fs) == 1 and "'put'" in fs[0].message

    def test_retry_forwarding_ignored(self):
        src = ('def f(c, retry):\n'
               '    c.request({"op": "put"}, retry=retry)\n')
        assert lint_source(src, "idempotent-retry-registry") == []

    def test_dynamic_header_needs_pragma(self):
        src = 'def f(c, h):\n    c.request(h, retry=True)\n'
        fs = lint_source(src, "idempotent-retry-registry")
        assert len(fs) == 1 and "retry-ops" in fs[0].message

    def test_retry_ops_pragma_resolves(self):
        src = ('def f(c, h):\n'
               '    # fabriclint: retry-ops=vs_get,vs_contains\n'
               '    c.request(h, retry=True)\n')
        assert lint_source(src, "idempotent-retry-registry") == []

    def test_retry_ops_pragma_still_checked_against_registry(self):
        src = ('def f(c, h):\n'
               '    # fabriclint: retry-ops=vs_put\n'
               '    c.request(h, retry=True)\n')
        fs = lint_source(src, "idempotent-retry-registry")
        assert len(fs) == 1 and "'vs_put'" in fs[0].message

    def test_registry_entries_have_justifications(self):
        for op, why in IDEMPOTENT_OPS.items():
            assert isinstance(why, str) and len(why.strip()) > 10, op


class TestGuardedLazyInit:
    BAD = """
class C:
    def get(self):
        if self._q is None:
            self._q = object()
        return self._q
"""
    GOOD = """
import threading
class C:
    def __init__(self):
        self._meta_lock = threading.RLock()
    def get(self):
        with self._meta_lock:
            if self._q is None:
                self._q = object()
            return self._q
"""

    def test_unguarded_flagged(self):
        fs = lint_source(self.BAD, "guarded-lazy-init")
        assert len(fs) == 1 and "_q" in fs[0].message

    def test_guarded_ok(self):
        assert lint_source(self.GOOD, "guarded-lazy-init") == []

    def test_or_condition_with_pid_check_still_flagged(self):
        src = """
import os
class C:
    def get(self):
        if self._q is None or self._pid != os.getpid():
            self._q = object()
        return self._q
"""
        assert len(lint_source(src, "guarded-lazy-init")) == 1

    def test_local_variable_not_flagged(self):
        src = """
def get(sock):
    if sock is None:
        sock = object()
    return sock
"""
        assert lint_source(src, "guarded-lazy-init") == []


class TestThreadLifecycle:
    def test_class_without_stop_flagged(self):
        src = """
import threading
class Leaky:
    def start(self):
        threading.Thread(target=self._loop, daemon=True).start()
"""
        fs = lint_source(src, "thread-lifecycle")
        assert len(fs) == 1 and "Leaky" in fs[0].message

    def test_class_with_stop_ok(self):
        src = """
import threading
class Fine:
    def start(self):
        threading.Thread(target=self._loop, daemon=True).start()
    def stop(self):
        pass
"""
        assert lint_source(src, "thread-lifecycle") == []

    def test_class_with_join_ok(self):
        src = """
import threading
class Fine:
    def run(self):
        t = threading.Thread(target=self._loop, daemon=True)
        t.start()
        t.join()
"""
        assert lint_source(src, "thread-lifecycle") == []

    def test_module_level_with_stop_flag_ok(self):
        src = """
import threading
def serve(stop):
    def loop():
        while not stop.is_set():
            pass
    threading.Thread(target=loop, daemon=True).start()
"""
        assert lint_source(src, "thread-lifecycle") == []

    def test_module_level_without_stop_flagged(self):
        src = """
import threading
def serve():
    def loop():
        while True:
            pass
    threading.Thread(target=loop, daemon=True).start()
"""
        assert len(lint_source(src, "thread-lifecycle")) == 1


class TestMonotonicDeadlines:
    def test_time_time_flagged(self):
        src = ("import time\n"
               "def expired(t0, lease):\n"
               "    return time.time() - t0 > lease\n")
        fs = lint_source(src, "monotonic-deadlines")
        assert len(fs) == 1 and "time.time()" in fs[0].message

    def test_perf_counter_ok(self):
        src = ("import time\n"
               "def stamp():\n"
               "    return time.perf_counter()\n")
        assert lint_source(src, "monotonic-deadlines") == []

    def test_sleep_ok(self):
        src = "import time\ndef nap():\n    time.sleep(0.1)\n"
        assert lint_source(src, "monotonic-deadlines") == []


class TestFrameHeaderHygiene:
    def test_pickled_blob_in_header_flagged(self):
        src = ('import pickle\n'
               'def f(c, x):\n'
               '    c.request({"op": "result", "v": pickle.dumps(x)})\n')
        fs = lint_source(src, "frame-header-hygiene")
        assert len(fs) == 1 and "blob" in fs[0].message

    def test_non_string_key_flagged(self):
        src = 'def f(c):\n    c.request({"op": "x", 1: "y"})\n'
        fs = lint_source(src, "frame-header-hygiene")
        assert len(fs) == 1 and "string literals" in fs[0].message

    def test_plain_header_ok(self):
        src = ('def f(c, topic, blob):\n'
               '    c.request({"op": "put", "topic": topic}, blob)\n')
        assert lint_source(src, "frame-header-hygiene") == []

    def test_relay_repickle_flagged(self):
        src = ('import pickle\n'
               'def relay(env):\n'
               '    return pickle.loads(env.data)\n')
        fs = lint_source(src, "frame-header-hygiene",
                         rel="src/repro/core/transport/broker.py")
        assert len(fs) == 1 and "single-pickle-per-hop" in fs[0].message

    def test_repickle_outside_relay_modules_ok(self):
        src = ('import pickle\n'
               'def decode(payload):\n'
               '    return pickle.loads(payload)\n')
        assert lint_source(src, "frame-header-hygiene",
                           rel="src/repro/core/value_server.py") == []

    def test_blob_under_shm_descriptor_key_flagged(self):
        src = ('import pickle\n'
               'def f(header, payload):\n'
               '    header["shm"] = pickle.dumps(payload)\n')
        fs = lint_source(src, "frame-header-hygiene")
        assert len(fs) == 1 and "descriptor" in fs[0].message

    def test_blob_under_meta_shm_key_flagged(self):
        src = ('import pickle\n'
               'def f(meta, payload):\n'
               '    meta["_shm"] = pickle.dumps(payload)\n')
        assert len(lint_source(src, "frame-header-hygiene")) == 1

    def test_plain_descriptor_assignment_ok(self):
        src = ('def f(header, desc):\n'
               '    header["shm"] = desc\n')
        assert lint_source(src, "frame-header-hygiene") == []


class TestShmSegmentLifecycle:
    def test_unguarded_create_flagged(self):
        src = ('from repro.core.transport import shm\n'
               'def export(scope, data):\n'
               '    desc = shm.create_segment(scope, data)\n'
               '    shm.sweep_scope(scope)\n'
               '    return desc\n')
        fs = lint_source(src, "shm-segment-lifecycle")
        assert len(fs) == 1 and "fallback" in fs[0].message

    def test_guarded_create_with_sweep_ok(self):
        src = ('from repro.core.transport import shm\n'
               'def export(scope, data):\n'
               '    try:\n'
               '        return shm.create_segment(scope, data)\n'
               '    except OSError:\n'
               '        return None\n'
               'def teardown(scope):\n'
               '    shm.sweep_scope(scope)\n')
        assert lint_source(src, "shm-segment-lifecycle") == []

    def test_create_without_scope_sweep_flagged(self):
        src = ('from repro.core.transport import shm\n'
               'def export(scope, data):\n'
               '    try:\n'
               '        return shm.create_segment(scope, data)\n'
               '    except OSError:\n'
               '        return None\n')
        fs = lint_source(src, "shm-segment-lifecycle")
        assert len(fs) == 1 and "sweep" in fs[0].message

    def test_consumer_unlink_flagged(self):
        src = ('from repro.core.transport import shm\n'
               'def consume(desc):\n'
               '    try:\n'
               '        data = shm.read_segment(desc)\n'
               '    except OSError:\n'
               '        return None\n'
               '    shm.unlink_segment(desc)\n'
               '    return data\n')
        fs = lint_source(src, "shm-segment-lifecycle")
        assert len(fs) == 1 and "ownership" in fs[0].message

    def test_unguarded_consumer_read_flagged(self):
        src = ('from repro.core.transport import shm\n'
               'def consume(desc):\n'
               '    return shm.read_segment(desc)\n')
        fs = lint_source(src, "shm-segment-lifecycle")
        assert len(fs) == 1 and "raced" in fs[0].message

    def test_broker_owns_its_reads_and_unlinks(self):
        # in the owner module an unguarded read and an unlink are the
        # protocol, not violations
        src = ('from repro.core.transport import shm\n'
               'def destroy(meta):\n'
               '    data = shm.read_segment(meta["_shm"])\n'
               '    shm.unlink_segment(meta["_shm"])\n'
               '    return data\n')
        assert lint_source(src, "shm-segment-lifecycle",
                           rel="src/repro/core/transport/broker.py") == []

    def test_shm_module_itself_exempt(self):
        src = ('import os\n'
               'def unlink_segment(desc):\n'
               '    os.unlink(desc["name"])\n')
        assert lint_source(src, "shm-segment-lifecycle",
                           rel="src/repro/core/transport/shm.py") == []


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------


def test_skip_pragma_requires_reason():
    flagged = ('import time\n'
               '# fabriclint: skip=monotonic-deadlines\n'
               'def f():\n'
               '    return time.time()\n')
    # a bare skip with no `-- reason` does NOT suppress
    src_ok = ('import time\n'
              'def f():\n'
              '    # fabriclint: skip=monotonic-deadlines -- test clock\n'
              '    return time.time()\n')
    assert len(lint_source(flagged, "monotonic-deadlines")) == 1
    assert lint_source(src_ok, "monotonic-deadlines") == []


def test_skip_pragma_is_pass_specific():
    src = ('import time\n'
           'def f():\n'
           '    # fabriclint: skip=guarded-lazy-init -- wrong pass\n'
           '    return time.time()\n')
    assert len(lint_source(src, "monotonic-deadlines")) == 1


# ---------------------------------------------------------------------------
# the CLI on the bad-code fixtures (one per pass) and on the repo
# ---------------------------------------------------------------------------

FIXTURE_EXPECT = [
    ("bad_wait_no_predicate.py", "wait-needs-predicate", 16),
    ("bad_retry_unregistered.py", "idempotent-retry-registry", 8),
    ("bad_lazy_init_unguarded.py", "guarded-lazy-init", 15),
    ("bad_thread_leak.py", "thread-lifecycle", 11),
    ("bad_wallclock_deadline.py", "monotonic-deadlines", 8),
    ("bad_header_pickle.py", "frame-header-hygiene", 11),
    ("bad_shm_consumer_unlink.py", "shm-segment-lifecycle", 14),
    ("bad_span_undeclared.py", "span-name-registry", 10),
]


def run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.fabriclint", *args],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


@pytest.mark.parametrize("fname,pass_name,line", FIXTURE_EXPECT)
def test_cli_flags_fixture(fname, pass_name, line):
    path = FIXTURES / fname
    res = run_cli("--check", str(path))
    assert res.returncode != 0, res.stdout + res.stderr
    # pass name AND file:line in the output
    assert pass_name in res.stdout
    assert f"{fname}:{line}" in res.stdout


def test_cli_clean_on_repo():
    res = run_cli("--check")
    assert res.returncode == 0, res.stdout + res.stderr
    assert "clean" in res.stdout


def test_every_pass_has_a_fixture():
    assert {p for _, p, _ in FIXTURE_EXPECT} == set(FL.PASSES)


# ---------------------------------------------------------------------------
# baseline ratchet
# ---------------------------------------------------------------------------


def test_baseline_grandfathers_old_findings(tmp_path):
    bad = FIXTURES / "bad_wallclock_deadline.py"
    findings = FL.run([bad])
    assert findings
    baseline = tmp_path / "baseline.json"
    FL.save_baseline(baseline, findings)
    res = run_cli("--check", "--baseline", str(baseline), str(bad))
    assert res.returncode == 0, res.stdout
    assert "baselined" in res.stdout
    # a finding NOT in the baseline still fails
    res2 = run_cli("--check", "--baseline", str(baseline),
                   str(FIXTURES / "bad_thread_leak.py"))
    assert res2.returncode != 0


def test_update_baseline_writes_current_set(tmp_path):
    bad = FIXTURES / "bad_retry_unregistered.py"
    baseline = tmp_path / "b.json"
    res = run_cli("--update-baseline", "--baseline", str(baseline),
                  str(bad))
    assert res.returncode == 0
    data = json.loads(baseline.read_text())
    assert len(data["findings"]) == 1
    assert data["findings"][0]["pass_name"] == "idempotent-retry-registry"


def test_checked_in_baseline_is_empty():
    data = json.loads((REPO / "analysis" / "baseline.json").read_text())
    assert data["findings"] == []


# ---------------------------------------------------------------------------
# regression: the genuine defect fabriclint surfaced (unguarded lazy
# init of the prefetch resolver in ShardedValueServer) stays fixed
# ---------------------------------------------------------------------------


def test_shards_prefetch_lazy_init_is_guarded():
    # the static pass is the primary guard: remove the lock and this fails
    shards = REPO / "src" / "repro" / "core" / "transport" / "shards.py"
    assert FL.run([shards], passes=["guarded-lazy-init"]) == []


def test_prefetch_builds_exactly_one_resolver_under_race(monkeypatch):
    import threading

    from repro.core.transport import shards as shards_mod
    from repro.core.transport.shards import ShardedValueServer

    vs = ShardedValueServer.__new__(ShardedValueServer)
    vs._init_client_state()
    monkeypatch.setattr(ShardedValueServer, "get",
                        lambda self, key: key, raising=True)

    created = []
    real_tpe = shards_mod.ThreadPoolExecutor

    class CountingExecutor(real_tpe):
        def __init__(self, *a, **k):
            created.append(self)
            super().__init__(*a, **k)

    monkeypatch.setattr(shards_mod, "ThreadPoolExecutor", CountingExecutor)

    n = 8
    barrier = threading.Barrier(n)
    futures = []
    fut_lock = threading.Lock()

    def go():
        barrier.wait()
        f = vs.prefetch("k")
        with fut_lock:
            futures.append(f)

    threads = [threading.Thread(target=go) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        # under the _meta_lock guard the race builds exactly one executor
        assert len(created) == 1
        assert [f.result(timeout=5) for f in futures] == ["k"] * n
    finally:
        vs._resolver.shutdown(wait=False)
