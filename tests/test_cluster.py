"""Multi-host cluster fabric: spec/partition agreement, broker
federation (cross-broker routing, leases and claims through the relay,
bundled snapshots), topology-aware straggler placement, the launcher's
simulated hosts, and kill-one-host chaos."""
import os
import signal
import threading
import time

import pytest

from repro.core import ColmenaQueues, ProcessPoolTaskServer
from repro.core.cluster import ClusterLauncher, ClusterSpec, HostSpec
from repro.core.cluster.spec import resolve_home
from repro.core.process_pool import dispatch_topic, host_of
from repro.core.transport import Envelope
from repro.core.transport.proc import ProcTransport
from repro.utils.timing import now


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

def test_spec_validation_and_partition():
    spec = ClusterSpec([
        HostSpec("h0", pools={"simulate": 2}, thinker=True),
        HostSpec("h1", pools={"simulate": 2, "train": 1}),
        HostSpec("h2", broker=False, pools={"train": 1}),
    ])
    assert spec.broker_hosts == ["h0", "h1"]
    assert spec.coordinator == "h0"
    assert spec.thinker_host == "h0"
    # topic homed with its first broker-running pool host
    part = spec.partition()
    assert part == {"simulate": "h0", "train": "h1"}
    # pool channels home at their host's broker; a brokerless host's
    # channels land deterministically on some member
    assert resolve_home(dispatch_topic("h1", "simulate"), part,
                        spec.broker_hosts) == "h1"
    assert resolve_home(dispatch_topic("h2", "train"), part,
                        spec.broker_hosts) in spec.broker_hosts
    assert spec.pool_hosts("train") == ["h1", "h2"]
    with pytest.raises(ValueError, match="duplicate"):
        ClusterSpec([HostSpec("a"), HostSpec("a")])
    with pytest.raises(ValueError, match="broker"):
        ClusterSpec([HostSpec("a", broker=False)])
    with pytest.raises(ValueError, match="without brokers"):
        ClusterSpec([HostSpec("a"), HostSpec("b", broker=False)],
                    partition={"t": "b"})
    with pytest.raises(ValueError, match="host name"):
        ClusterSpec([HostSpec("a/b")])
    # explicit overrides win
    spec2 = ClusterSpec([HostSpec("h0", pools={"t": 1}), HostSpec("h1")],
                        partition={"t": "h1"})
    assert spec2.partition()["t"] == "h1"


# ---------------------------------------------------------------------------
# federation (broker-only launchers: the relay layer in isolation)
# ---------------------------------------------------------------------------

@pytest.fixture
def federation():
    """Two federated brokers; topic "t" homed at h1, so every h0-client
    frame for it crosses the relay."""
    spec = ClusterSpec([HostSpec("h0"), HostSpec("h1")],
                       partition={"t": "h1"}, lease_timeout=0.5)
    lc = ClusterLauncher(spec).start()
    transports = []

    def dial(host):
        t = ProcTransport(address=lc.address_of(host), lease_timeout=0.5)
        transports.append(t)
        return t

    yield lc, dial
    lc.stop()


def test_cross_broker_routing_roundtrip(federation):
    lc, dial = federation
    t0, t1 = dial("h0"), dial("h1")
    ch0 = t0.channel("t", "requests")
    ch0.put(Envelope(now(), b"payload", {"task_id": "a"}))  # relayed
    # both members see the same queue (h1 owns it; h0 relays the len)
    assert len(ch0) == 1
    assert len(t1.channel("t", "requests")) == 1
    env = ch0.get(timeout=2)            # leased dequeue through the relay
    assert env is not None and env.data == b"payload"
    assert env.meta["task_id"] == "a"
    ch0.ack(flush=True)                 # ack routes home by topic
    time.sleep(0.7)                     # well past lease_timeout
    assert ch0.get(timeout=0.3) is None  # acked: never redelivered


def test_lease_expiry_redelivers_through_relay(federation):
    lc, dial = federation
    ch = dial("h0").channel("t", "requests")
    ch.put(Envelope(now(), b"x", {"task_id": "b"}))
    got = []
    th = threading.Thread(target=lambda: got.extend(
        ch.get_batch(1, timeout=2)))
    th.start()
    th.join()                           # thread dies holding the lease
    assert len(got) == 1
    env = ch.get(timeout=3)             # expiry runs at the home broker
    assert env is not None and env.meta["redelivered"] == 1
    ch.ack(flush=True)


def test_put_claim_dedups_across_members(federation):
    lc, dial = federation
    ch0 = dial("h0").channel("t", "results")
    ch1 = dial("h1").channel("t", "results")
    # two publishers racing through *different* local brokers arbitrate
    # at the topic's home
    assert ch0.put(Envelope(now(), b"win", {}), claim="tid-1") is True
    assert ch1.put(Envelope(now(), b"lose", {}), claim="tid-1") is False
    assert len(ch0) == 1
    assert ch0.get(timeout=1).data == b"win"
    ch0.ack(flush=True)


def test_federated_snapshot_restore_bundle(federation):
    lc, dial = federation
    t0 = dial("h0")
    reqs = t0.channel("t", "requests")          # homed h1
    local = t0.channel("elsewhere", "requests")  # hashed somewhere
    for i in range(3):
        reqs.put(Envelope(now(), b"task%d" % i, {"task_id": str(i)}))
    local.put(Envelope(now(), b"other", {"task_id": "z"}))
    t0.channel("t", "results").put(Envelope(now(), b"done", {}),
                                   claim="done-id")
    snap = t0.snapshot()

    spec2 = ClusterSpec([HostSpec("h0"), HostSpec("h1")],
                        partition={"t": "h1"}, lease_timeout=0.5)
    with ClusterLauncher(spec2).start() as lc2:
        t2 = ProcTransport(address=lc2.address_of("h0"), lease_timeout=0.5)
        t2.restore(snap)
        # identical federation state -> identical bundle bytes
        assert t2.snapshot() == snap
        assert len(t2.channel("t", "requests")) == 3
        assert len(t2.channel("elsewhere", "requests")) == 1
        assert len(t2.channel("t", "results")) == 1
        # the claim window restored at the topic's home still dedups
        assert t2.channel("t", "results").put(
            Envelope(now(), b"dup", {}), claim="done-id") is False
        t2.client.close()


# ---------------------------------------------------------------------------
# topology-aware straggler placement (two pools, one shared broker)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cross_host_backup_lands_on_other_host():
    queues = ColmenaQueues(["t"], backend="proc", lease_timeout=5.0)

    def task(x):
        time.sleep(x)
        return os.getpid()

    pools = []
    try:
        for host in ("hA", "hB"):
            pool = ProcessPoolTaskServer(
                queues, workers_per_topic=1, host=host,
                backup_hosts={"t": [h for h in ("hA", "hB") if h != host]},
                straggler_factor=3.0, straggler_min_history=1)
            pool.register(task, name="t")
            pools.append(pool)
        for pool in pools:
            pool.start()
        # warm the runtime history of BOTH hosts (whichever host holds
        # the slow task needs history for its monitor to fire)
        warm = 0
        deadline = time.time() + 20
        while (any(not p._runtimes.get("t") for p in pools)
               and time.time() < deadline):
            queues.send_task(0.0, method="t", topic="t")
            warm += 1
            queues.get_result("t", timeout=10)
        assert all(p._runtimes.get("t") for p in pools), "warmup starved"
        tid = queues.send_task(1.2, method="t", topic="t")
        r = queues.get_result("t", timeout=30)
        assert r is not None and r.success
        # exactly one pool (the origin's) fired a backup...
        firing = [p for p in pools if tid in p.backup_targets]
        assert len(firing) == 1, "straggler backup never fired"
        origin_pool = firing[0]
        target = origin_pool.backup_targets[tid]
        # ...and placed it on the other host
        assert target != origin_pool.host
        # the backup demonstrably *started* on the other host
        other_pool = next(p for p in pools if p is not origin_pool)
        hist_dl = time.time() + 10
        while (not other_pool.task_history.get(tid)
               and time.time() < hist_dl):
            time.sleep(0.05)
        backup_starts = other_pool.task_history.get(tid, [])
        assert backup_starts, "backup never started on the peer host"
        assert all(host_of(i) == other_pool.host for i in backup_starts)
        # exactly-once completion despite the race
        assert queues.get_result("t", timeout=1.0) is None
        assert queues.active_count == 0
    finally:
        for pool in pools:
            pool.stop()
        queues.shutdown()


# ---------------------------------------------------------------------------
# launcher: 2 simulated hosts end to end
# ---------------------------------------------------------------------------

def _times_ten(x):
    time.sleep(0.05)
    return x * 10


@pytest.mark.slow
def test_two_host_campaign_exactly_once():
    spec = ClusterSpec([
        HostSpec("h0", pools={"t": 2}, thinker=True),
        HostSpec("h1", pools={"t": 2}),
    ], lease_timeout=5.0)
    with ClusterLauncher(spec,
                         methods=[(_times_ten, {"topic": "t",
                                                "name": "t"})]) as lc:
        queues = lc.connect()
        try:
            values = {}
            submitted = [queues.send_task(i, method="t", topic="t")
                         for i in range(24)]
            for i, tid in enumerate(submitted):
                values[tid] = i * 10
            results = {}
            workers = set()
            for _ in submitted:
                r = queues.get_result("t", timeout=60)
                assert r is not None and r.success, r and r.error
                assert r.task_id not in results, "duplicate completion"
                results[r.task_id] = r.value
                workers.add(host_of(r.worker))
            # keep the campaign going until BOTH hosts have won work (a
            # scheduler can let one host's intake start first; a healthy
            # peer pool must still win leases well before the deadline)
            deadline = time.time() + 60
            extra = 24
            while workers != {"h0", "h1"} and time.time() < deadline:
                tid = queues.send_task(extra, method="t", topic="t")
                submitted.append(tid)
                values[tid] = extra * 10
                extra += 1
                r = queues.get_result("t", timeout=60)
                assert r is not None and r.success
                assert r.task_id not in results, "duplicate completion"
                results[r.task_id] = r.value
                workers.add(host_of(r.worker))
            assert workers == {"h0", "h1"}, f"a host never won work: {workers}"
            assert set(results) == set(submitted)   # exactly-once, zero lost
            for tid, want in values.items():
                assert results[tid] == want
            # nothing else ever arrives; the campaign is quiescent
            assert queues.get_result("t", timeout=1.0) is None
            assert queues.active_count == 0
        finally:
            queues.shutdown()


def _slow_sim(x):
    time.sleep(0.5)
    return x + 1000


@pytest.mark.slow
def test_kill_one_host_redelivers_to_survivor():
    """Node-loss chaos: SIGKILL one host's whole pool process group
    mid-campaign.  Its queued dispatch envelopes are rescued back to the
    global topic, its in-flight leases expire into the same drain, and
    the surviving host finishes the campaign -- zero lost, zero
    duplicated.  The kill lands while every task is still executing or
    queued (tasks take 0.5 s; we kill at 0.2 s), so *every* completion
    must come from the survivor."""
    spec = ClusterSpec([
        HostSpec("h0", pools={"t": 2}, thinker=True),
        HostSpec("h1", pools={"t": 2}),
    ], lease_timeout=1.0)
    with ClusterLauncher(spec,
                         methods=[(_slow_sim, {"topic": "t",
                                               "name": "t"})]) as lc:
        queues = lc.connect()
        try:
            submitted = [queues.send_task(i, method="t", topic="t")
                         for i in range(14)]
            # let both hosts lease work, but kill before any 0.5s task
            # can possibly have completed
            time.sleep(0.2)
            lc.kill_host("h1")
            results = {}
            for _ in submitted:
                r = queues.get_result("t", timeout=60)
                assert r is not None and r.success, r and r.error
                assert r.task_id not in results, "duplicate completion"
                # the victim died pre-completion: only the survivor wins
                assert host_of(r.worker) == "h0"
                results[r.task_id] = r.value
            assert set(results) == set(submitted)   # zero lost
            assert queues.get_result("t", timeout=1.5) is None  # zero dup
            assert queues.active_count == 0
        finally:
            queues.shutdown()


# ---------------------------------------------------------------------------
# cluster Value Server shards + ssh hook + auto-snapshot
# ---------------------------------------------------------------------------

def test_cluster_vs_shards_shared_ring():
    from repro.core.transport.shards import ShardedValueServer
    spec = ClusterSpec([HostSpec("h0", vs_shards=1),
                        HostSpec("h1", vs_shards=1)])
    with ClusterLauncher(spec) as lc:
        assert len(lc.vs_addresses) == 2
        a = ShardedValueServer.connect(lc.vs_addresses)
        b = ShardedValueServer.connect(lc.vs_addresses)
        key = a.put({"x": list(range(100))})
        # a second client with the same ordered ring resolves the key
        assert b.get(key) == {"x": list(range(100))}
        assert a.shard_of(key) == b.shard_of(key)
        # connected clients do not own the shards
        a.shutdown()
        assert key in b


def test_ssh_command_hook(tmp_path):
    spec = ClusterSpec([
        HostSpec("h0", pools={"t": 2}, thinker=True),
        HostSpec("h1", pools={"t": 4}, ssh="user@node17"),
    ])
    lc = ClusterLauncher(spec, methods=[("repro.apps.synapp:syntask",
                                         {"topic": "t"})])
    lc._addresses = {"h0": ("tcp", "10.0.0.1", 5000),
                     "h1": ("tcp", "10.0.0.2", 5000)}
    cmds = lc.ssh_commands(str(tmp_path))
    assert list(cmds) == ["h1"]
    cmd = cmds["h1"]
    assert cmd[:2] == ["ssh", "user@node17"]
    assert "repro.core.cluster.agent" in cmd
    cfg_path = cmd[-1]
    assert os.path.exists(cfg_path)
    import pickle
    with open(cfg_path, "rb") as f:
        cfg = pickle.load(f)
    assert cfg.host == "h1" and cfg.pools == {"t": 4}
    assert cfg.broker_address == ("tcp", "10.0.0.2", 5000)
    # callables cannot travel over ssh
    lc2 = ClusterLauncher(spec, methods=[(_times_ten, {"topic": "t"})])
    lc2._addresses = lc._addresses
    with pytest.raises(ValueError, match="module:qualname"):
        lc2.write_agent_configs(str(tmp_path))


def test_broker_auto_snapshot_resumable(tmp_path):
    path = str(tmp_path / "auto.snap")
    queues = ColmenaQueues(["t"], backend="proc", lease_timeout=2.0,
                           snapshot_every=0.15, snapshot_path=path)
    try:
        for i in range(3):
            queues.send_task(i, method="t", topic="t")
        deadline = time.time() + 10
        while not os.path.exists(path) and time.time() < deadline:
            time.sleep(0.05)
        assert os.path.exists(path), "auto-snapshot never written"
        time.sleep(0.3)                 # at least one post-put snapshot
        payload = ColmenaQueues.load_checkpoint(path)
        # no application recorded the count: derived from envelope metas
        assert payload["active"] == 3
        assert payload["extra"] is None
        fresh = ColmenaQueues(["t"], backend="proc")
        try:
            assert fresh.resume(path, payload=payload) is None
            assert fresh.active_count == 3
            tasks = fresh.get_tasks("t", max_n=10, timeout=2)
            assert sorted(t.args[0] for t in tasks) == [0, 1, 2]
        finally:
            fresh.shutdown()
    finally:
        queues.shutdown()


def test_local_backend_rejects_auto_snapshot(tmp_path):
    with pytest.raises(ValueError, match="proc"):
        ColmenaQueues(["t"], backend="local", snapshot_every=1.0,
                      snapshot_path=str(tmp_path / "x"))
    from repro.core.transport import make_transport
    t = make_transport("local")
    with pytest.raises(ValueError, match="snapshot_every"):
        ColmenaQueues(["t"], transport=t, snapshot_every=1.0,
                      snapshot_path=str(tmp_path / "x"))


def test_derived_active_excludes_consumed_but_leased(tmp_path):
    """The piggyback-ack window: a snapshot can image a worker's
    dispatch lease for a task whose result was already published,
    consumed, and acked.  Counting it active would hang a resumed
    wait_until_done (the re-execution loses the restored claim and
    never delivers) -- claimed ids with no queued result envelope are
    excluded from the derived count."""
    from repro.core.transport import Envelope, make_transport
    t = make_transport("proc", lease_timeout=30.0)
    try:
        dispatch = t.channel(dispatch_topic("h0", "t"), "tasks")
        results = t.channel("t", "results")
        # stale: executed, result published+claimed, result consumed and
        # acked -- but the dispatch lease was never acked (worker died
        # with the ack still piggyback-pending)
        dispatch.put(Envelope(now(), b"stale", {"task_id": "done-task"}))
        got = []
        th = threading.Thread(
            target=lambda: got.extend(dispatch.get_batch(1, timeout=2)))
        th.start()
        th.join()
        assert len(got) == 1                # leased, never acked
        assert results.put(Envelope(now(), b"r", {"task_id": "done-task"}),
                           claim="done-task") is True
        assert results.get(timeout=2) is not None
        results.ack(flush=True)             # consumed: result is gone
        # live: a second task still genuinely in flight
        dispatch.put(Envelope(now(), b"live", {"task_id": "live-task"}))
        snap = t.snapshot()
        path = str(tmp_path / "auto.snap")
        with open(path, "wb") as f:
            f.write(snap)
        payload = ColmenaQueues.load_checkpoint(path)
        assert payload["active"] == 1       # live-task only
    finally:
        t.close()


# ---------------------------------------------------------------------------
# durable Value Server at cluster scale: replica survival, shard restart,
# and the kill -9'd campaign that resumes WITH the Value Server enabled
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cluster_vs_replicas_survive_host_kill_and_restore():
    """kill_host takes the host's shard processes with it (node loss).
    With vs_replicas=2 every key stays readable -- byte-identical --
    via its ring successor; restore_host_shards then rebuilds the
    replica factor and stale clients converge by redirect."""
    spec = ClusterSpec([
        HostSpec("h0", vs_shards=1, pools={"t": 1}, thinker=True),
        HostSpec("h1", vs_shards=1, pools={"t": 1}),
    ], vs_replicas=2, lease_timeout=3.0)
    with ClusterLauncher(spec) as lc:
        vs = lc.value_server()
        assert vs.replicas == 2             # adopted from the pushed ring
        vals = {vs.put(os.urandom(400), sync=True): None for _ in range(20)}
        vals = {k: vs.get(k) for k in vals}
        lc.kill_host("h1")
        for k, v in vals.items():
            assert vs.get(k) == v           # replicas cover the dead shard
        assert vs.client_stats["replica_reads"] > 0
        replaced = lc.restore_host_shards("h1")
        assert len(replaced) == 1 and replaced[0]["host"] == "h1"
        fresh = lc.value_server()
        assert fresh._epoch > 1
        for k, v in vals.items():
            assert fresh.get(k) == v
        # replica factor is fully restored: every key has 2 copies again
        assert sum(s["len"] for s in fresh.per_shard_stats()) == 2 * len(vals)
        # the stale pre-kill client is redirected onto the new ring
        for k, v in vals.items():
            assert vs.get(k) == v
        assert vs._epoch == fresh._epoch
        assert vs.client_stats["redirects"] >= 1


def _echo_payload(payload: bytes):
    time.sleep(0.2)
    return payload[:16]


@pytest.mark.slow
def test_cluster_campaign_kill9_resume_with_value_server(tmp_path):
    """The acceptance scenario: a 2-host cluster campaign with the Value
    Server ENABLED (inputs proxied through the shard ring) is checkpointed
    mid-flight, the whole incarnation is SIGKILLed -- agents, brokers,
    shards -- and a fresh cluster resumes from the file: zero lost ids,
    zero duplicated ids, and every restored proxy resolves (results echo
    their input payload's prefix, which only resolves through the VS)."""
    path = str(tmp_path / "cluster.ckpt")
    spec = ClusterSpec([
        HostSpec("h0", pools={"t": 1}, vs_shards=1, thinker=True),
        HostSpec("h1", pools={"t": 1}, vs_shards=1),
    ], vs_replicas=2, lease_timeout=2.0)
    payloads = {}
    with ClusterLauncher(spec, methods=[(_echo_payload,
                                         {"topic": "t", "name": "t"})],
                         proxy_threshold=1 << 10) as lc:
        vs = lc.value_server()
        queues = lc.connect(["t"], value_server=vs,
                            proxy_threshold=1 << 10)
        submitted = []
        for i in range(10):
            data = bytes([i]) * 2048        # above threshold: proxied
            tid = queues.send_task(data, method="t", topic="t")
            submitted.append(tid)
            payloads[tid] = data
        consumed = {}
        for _ in range(3):
            r = queues.get_result("t", timeout=60)
            assert r is not None and r.success, r and r.error
            consumed[r.task_id] = r.value
        queues.checkpoint(path)
        # kill -9 the whole incarnation: agents (process groups), every
        # broker, every shard -- nothing survives but the file
        for host, p in list(lc._agents.items()):
            try:
                os.killpg(p.pid, signal.SIGKILL)
            except (ProcessLookupError, PermissionError):
                pass
        for e in lc._shards:
            e["proc"].kill()
        for name, p in lc._brokers.items():
            p.kill()
        try:
            queues.transport.client.close()
        except Exception:
            pass
    # fresh incarnation, same spec shape
    spec2 = ClusterSpec([
        HostSpec("h0", pools={"t": 1}, vs_shards=1, thinker=True),
        HostSpec("h1", pools={"t": 1}, vs_shards=1),
    ], vs_replicas=2, lease_timeout=2.0)
    with ClusterLauncher(spec2, methods=[(_echo_payload,
                                          {"topic": "t", "name": "t"})],
                         proxy_threshold=1 << 10) as lc2:
        vs2 = lc2.value_server()
        q2 = lc2.connect(["t"], value_server=vs2, proxy_threshold=1 << 10)
        try:
            assert q2.resume(path) is None
            assert q2.active_count == len(submitted) - len(consumed)
            recovered = {}
            for _ in range(q2.active_count):
                r = q2.get_result("t", timeout=90)
                assert r is not None and r.success, r and r.error
                assert r.task_id not in consumed    # never redone
                assert r.task_id not in recovered   # never duplicated
                recovered[r.task_id] = r.value
            # zero lost: every submitted id completed exactly once, and
            # every completion echoes its ORIGINAL proxied payload
            done = {**consumed, **recovered}
            assert set(done) == set(submitted)
            for tid, value in done.items():
                assert value == payloads[tid][:16]
            assert q2.get_result("t", timeout=1.5) is None  # quiescent
            assert q2.active_count == 0
        finally:
            q2.shutdown()
