"""Substrate layers: sharding rules, optimizer, checkpointing, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import store
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig, get_config
from repro.data import molecules, tokens
from repro.distributed import sharding as shd
from repro.optim import adamw, clip, schedules


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.array(jax.devices()[:1] * (shape[0] * shape[1]))
    # host has 1 device; use abstract mesh via make_mesh only when enough
    # devices exist.  For rule tests we only need the .shape mapping:
    class FakeMesh:
        def __init__(self):
            self.shape = dict(zip(axes, shape))
    return FakeMesh()


def test_spec_for_tp_rules():
    mesh = _mesh((2, 4))
    # ff divisible -> model; embed replicated
    assert shd.spec_for(("embed", "ff"), (128, 512), mesh) == P(None, "model")
    # vocab divisible -> model
    assert shd.spec_for(("vocab", "embed"), (1024, 128), mesh) == \
        P("model", None)
    # non-divisible falls back to replication
    assert shd.spec_for(("kv_heads", "head_dim"), (3, 64), mesh) == \
        P(None, None)
    # a mesh axis is never used twice
    spec = shd.spec_for(("ff", "experts"), (512, 8), mesh)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used))


def test_spec_for_fsdp_adds_data_axis():
    mesh = _mesh((4, 4))
    spec = shd.spec_for(("embed", "ff"), (1024, 4096), mesh, mode="fsdp_tp")
    assert spec == P("data", "model")
    # small params stay replicated even in fsdp mode
    spec_small = shd.spec_for(("embed",), (128,), mesh, mode="fsdp_tp")
    assert spec_small == P(None)


def test_zero_spec_shards_moments():
    mesh = _mesh((4, 4))
    zs = shd.zero_spec(P(None, "model"), (1024, 4096), mesh)
    assert zs == P("data", "model")
    # already data-sharded spec untouched
    assert shd.zero_spec(P("data", None), (1024, 64), mesh) == P("data", None)


def test_batch_axes_divisibility():
    mesh3 = _mesh((2, 16, 16), ("pod", "data", "model"))
    assert shd.batch_axes(mesh3, 256) == ("pod", "data")
    assert shd.batch_axes(mesh3, 1) == ()
    mesh2 = _mesh((16, 16), ("data", "model"))
    assert shd.batch_axes(mesh2, 128) == ("data",)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    tc = TrainConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                     total_steps=100)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}        # d/dw of w^2
        params, state = adamw.update(grads, state, params, 0.05, tc)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    from repro.utils.trees import tree_global_norm
    assert abs(float(tree_global_norm(clipped)) - 1.0) < 1e-4


def test_nonfinite_guard():
    g = {"a": jnp.asarray([1.0, jnp.nan])}
    fixed, bad = clip.zero_nonfinite(g)
    assert bool(bad)
    assert float(jnp.sum(jnp.abs(fixed["a"]))) == 0.0


def test_warmup_cosine_schedule():
    kw = dict(lr=1.0, warmup_steps=10, total_steps=100)
    s0 = float(schedules.warmup_cosine(jnp.asarray(0), **kw))
    s10 = float(schedules.warmup_cosine(jnp.asarray(10), **kw))
    s100 = float(schedules.warmup_cosine(jnp.asarray(100), **kw))
    assert s0 == 0.0 and abs(s10 - 1.0) < 0.01 and s100 <= 0.11


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_store_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.ones((4, 3), jnp.bfloat16) * 1.5,
            "b": {"c": jnp.arange(5, dtype=jnp.int32)},
            "s": jnp.asarray(2.0)}
    path = str(tmp_path / "ck")
    store.save(path, tree)
    back = store.restore(path, tree)
    assert back["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])


def test_manager_rotation_and_corruption_fallback(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.arange(10, dtype=jnp.float32)}
    for step in (1, 2, 3):
        m.save(step, {"x": tree["x"] * step}, blocking=True)
    assert m.steps() == [2, 3]            # rotated
    # corrupt the newest shard
    import os
    shard = os.path.join(str(tmp_path), "step_3", store.SHARD)
    with open(shard, "wb") as f:
        f.write(b"garbage")
    step, back = m.restore(tree)
    assert step == 2                       # fell back to older valid ckpt
    np.testing.assert_array_equal(back["x"], tree["x"] * 2)


def test_train_resume_bitexact(tmp_path):
    """Fault-tolerance: resume reproduces the uninterrupted run."""
    from repro.launch.train import train
    kw = dict(reduced=True, batch=2, seq=32, lr=1e-3, log_every=100,
              print_fn=lambda *a: None)
    # uninterrupted 8 steps
    s_full, _ = train("internlm2-1.8b", steps_total=8, **kw)
    # interrupted at 4 + resume (same schedule: steps_total stays 8)
    ck = str(tmp_path / "ck")
    train("internlm2-1.8b", steps_total=8, stop_after=4, ckpt_dir=ck,
          ckpt_every=100, **kw)
    s_res, _ = train("internlm2-1.8b", steps_total=8, ckpt_dir=ck,
                     resume=True, **kw)
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_res["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_lm_batches_deterministic():
    cfg = get_config("internlm2-1.8b", reduced=True)
    b1 = tokens.lm_batch(cfg, 4, 16, step=7, seed=0)
    b2 = tokens.lm_batch(cfg, 4, 16, step=7, seed=0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = tokens.lm_batch(cfg, 4, 16, step=8, seed=0)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    assert b1["tokens"].max() < cfg.vocab_size


def test_molecules_deterministic_and_oracle_range():
    space = molecules.MoleculeSpace(num_molecules=100)
    a1 = molecules.generate_molecule(space, 7)
    a2 = molecules.generate_molecule(space, 7)
    np.testing.assert_array_equal(a1[1], a2[1])
    vals = molecules.oracle_batch(space, range(50))
    assert np.all(vals > 3.9) and np.all(vals < 12.1)
    assert vals.std() > 0.1                # non-degenerate landscape
    # symmetric bonds
    assert np.array_equal(a1[1], a1[1].T)


def test_prefetch_loader_order():
    from repro.data.loader import PrefetchLoader
    loader = PrefetchLoader(lambda step: step * 10, start_step=3, depth=2)
    got = [next(loader) for _ in range(3)]
    loader.close()
    assert got == [(3, 30), (4, 40), (5, 50)]
