"""Inference shard tests: bucketed micro-batching, the serve loop over
stub engines (no jax -- these pin the fabric semantics, not the model),
the detached-lease channel API it is built on, and the SIGKILL chaos
story (lease expiry redelivers every in-flight request exactly once).
"""
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.queues import ColmenaQueues
from repro.core.transport import Envelope, make_transport
from repro.serving.batcher import (DecodeGroup, InferenceRequest,
                                   MicroBatch, MicroBatcher, batch_bucket,
                                   prompt_bucket)
from repro.serving.shard import (InferenceClient, ServeLoop, ServeSpec,
                                 send_shard_stop, start_inference_shard)
from repro.utils.timing import now


def _req(tid, tokens, max_new=4, t=0.0):
    return InferenceRequest(task_id=tid, tokens=list(tokens),
                            max_new=max_new, enqueue_t=t)


# ---------------------------------------------------------------------------
# batcher: pure bookkeeping
# ---------------------------------------------------------------------------

def test_prompt_and_batch_buckets():
    assert prompt_bucket(1, (16, 32)) == 16
    assert prompt_bucket(16, (16, 32)) == 16
    assert prompt_bucket(17, (16, 32)) == 32
    with pytest.raises(ValueError):
        prompt_bucket(33, (16, 32))
    assert [batch_bucket(n, 8) for n in (1, 2, 3, 5, 8, 30)] \
        == [1, 2, 4, 8, 8, 8]


def test_microbatcher_ragged_arrival_splits_full_then_partial():
    """N not a multiple of max_batch: full batches flush immediately,
    the ragged remainder waits for its deadline."""
    mb = MicroBatcher(max_batch=4, prompt_buckets=(16,),
                      max_batch_delay=10.0)
    for i in range(9):
        mb.add(_req(f"t{i}", [1] * 5, t=0.0))
    ready = mb.pop_ready(tnow=0.001)
    assert [len(b.requests) for b in ready] == [4, 4]
    # FIFO within the bucket
    assert [r.task_id for r in ready[0].requests] == ["t0", "t1", "t2", "t3"]
    assert mb.pending_count() == 1
    # the remainder is deadline-gated ...
    assert mb.pop_ready(tnow=0.002) == []
    assert mb.next_deadline() == pytest.approx(10.0)
    # ... and flushes as a partial batch once the oldest waited out
    late = mb.pop_ready(tnow=10.5)
    assert [len(b.requests) for b in late] == [1]
    assert late[0].requests[0].task_id == "t8"
    assert mb.pending_count() == 0


def test_microbatcher_force_flush_and_bucket_separation():
    mb = MicroBatcher(max_batch=8, prompt_buckets=(8, 16),
                      max_batch_delay=10.0)
    mb.add(_req("a", [1] * 3, t=0.0))     # bucket 8
    mb.add(_req("b", [1] * 12, t=0.0))    # bucket 16
    assert mb.pop_ready(tnow=0.0) == []
    ready = mb.pop_ready(tnow=0.0, force=True)
    assert sorted(b.bucket for b in ready) == [8, 16]
    assert mb.pending_count() == 0


def test_padded_tokens_left_pads_and_repeats_row0():
    m = MicroBatch(8, [_req("a", [5, 6, 7]), _req("b", [9])])
    out = m.padded_tokens(padded_b=4)
    assert out.shape == (4, 8)
    assert list(out[0]) == [0] * 5 + [5, 6, 7]
    assert list(out[1]) == [0] * 7 + [9]
    # padding rows repeat row 0: no novel content, outputs dropped
    assert (out[2] == out[0]).all() and (out[3] == out[0]).all()


def test_decode_group_early_retire_and_compaction():
    m = MicroBatch(8, [_req("a", [1], max_new=1), _req("b", [2], max_new=1),
                       _req("c", [3], max_new=1), _req("d", [4], max_new=3)])
    g = DecodeGroup(m, first_tokens=[10, 20, 30, 40], max_batch=8)
    # max_new=1 rows are finished right after the prefill token
    done = {r.task_id: toks for r, toks in g.finished()}
    assert done == {"a": [10], "b": [20], "c": [30]}
    g.retire_finished()
    assert [r.task_id for r in g.rows] == ["d"] and g.slots == [3]
    # survivor fits batch bucket 1 < padded_b 4 -> compaction
    assert g.compaction(padded_b=4) == 1
    g.reset_slots()
    assert g.slots == [0]
    # post-compaction decode steps index the gathered state
    g.record_step([41])
    g.record_step([42])
    ((r, toks),) = g.finished()
    assert r.task_id == "d" and toks == [40, 41, 42]
    g.retire_finished()
    assert g.done


# ---------------------------------------------------------------------------
# the channel API the shard's lease discipline rides on
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["local", "proc"])
def test_detach_lease_survives_next_get(backend):
    """detach_lease takes over the lease lifetime: the next get_batch no
    longer implicitly commits it, an unacked detached lease expires and
    redelivers, and ack_lease commits it for good."""
    t = make_transport(backend, lease_timeout=0.5)
    try:
        ch = t.channel("t", "requests")
        ch.put(Envelope(now(), b"one", {}))
        ch.put(Envelope(now(), b"two", {}))
        (e1,) = ch.get_batch(1, timeout=2.0)
        lid1 = ch.detach_lease()
        assert lid1 is not None
        # poll-is-commit must NOT touch the detached lease
        (e2,) = ch.get_batch(1, timeout=2.0)
        ch.ack(flush=True)                  # commits e2's lease only
        deadline = now() + 5.0
        redelivered = []
        while not redelivered and now() < deadline:
            redelivered = ch.get_batch(1, timeout=0.5)
        assert [e.data for e in redelivered] == [b"one"]
        assert redelivered[0].meta.get("redelivered", 0) >= 1
        # now commit the redelivery explicitly, as the shard does
        lid = ch.detach_lease()
        ch.ack_lease(lid, flush=True)
        time.sleep(0.7)                     # past expiry: stays committed
        assert ch.get_batch(1, timeout=0.05) == []
    finally:
        t.close()


# ---------------------------------------------------------------------------
# serve loop over a stub engine (local transport, in-thread shard)
# ---------------------------------------------------------------------------

class _StubState:
    def __init__(self, cur, padded_b):
        self.cur = cur
        self.padded_b = padded_b


class _StubEngine:
    """Echo chain: first = last prompt token + 1, each step +1.  Records
    the shapes it sees so tests can assert bucketing and compaction."""

    def __init__(self, step_sleep=0.0):
        self.step_sleep = step_sleep
        self.prefill_shapes = []
        self.gather_sizes = []

    def prefill_batch(self, tokens, *, reserve=None, frames=None):
        self.prefill_shapes.append(tokens.shape)
        first = tokens[:, -1].astype(np.int64) + 1
        return first, _StubState(first, tokens.shape[0])

    def decode_batch(self, state):
        if self.step_sleep:
            time.sleep(self.step_sleep)
        state.cur = state.cur + 1
        return state.cur

    def gather_rows(self, state, rows):
        idx = np.asarray(list(rows))
        self.gather_sizes.append(len(idx))
        return _StubState(state.cur[idx], len(idx))


def _stub_factory():
    return _StubEngine()


def _slow_stub_factory():
    return _StubEngine(step_sleep=0.05)


def _local_shard(spec, engine=None):
    q = ColmenaQueues([], backend="local", serve_spec=spec)
    loop = ServeLoop(q.transport, spec, engine=engine,
                     identity="infer@test:0")
    th = threading.Thread(target=loop.run, daemon=True, name="test-shard")
    th.start()
    return q, loop, th


def _stop_local(q, spec, th):
    send_shard_stop(q.transport, spec.topic)
    th.join(timeout=5)
    assert not th.is_alive()


def test_serve_loop_end_to_end_ragged():
    """Ragged arrival across buckets: every request answered with the
    right echo chain, reassembled in submission order."""
    spec = ServeSpec(engine_factory=_stub_factory, max_batch=4,
                     prompt_buckets=(8, 16), max_batch_delay_ms=5.0)
    eng = _StubEngine()
    q, loop, th = _local_shard(spec, engine=eng)
    try:
        client = InferenceClient(q)
        prompts = [[3, 4], [10], [7] * 12, [1, 2, 3], [20] * 5]
        res = client.infer(prompts, max_new=3, timeout=20.0)
        for p, r in zip(prompts, res):
            assert r.success, r.error
            assert r.value == [p[-1] + 1, p[-1] + 2, p[-1] + 3]
        assert q.active_count == 0
        # prompts landed in their length buckets, batch dims are pow2
        for (b, s) in eng.prefill_shapes:
            assert s in (8, 16) and b in (1, 2, 4)
    finally:
        _stop_local(q, spec, th)
    assert loop.stats["published"] == 5
    assert loop.stats["claim_lost"] == 0


def test_serve_loop_max_new_1_and_deadline_partial_flush():
    """max_new=1 rows stream straight from the prefill (zero decode
    steps), and a lone request flushes as a deadline-expired partial
    batch rather than waiting for company."""
    spec = ServeSpec(engine_factory=_stub_factory, max_batch=8,
                     prompt_buckets=(8,), max_batch_delay_ms=30.0)
    eng = _StubEngine()
    q, loop, th = _local_shard(spec, engine=eng)
    try:
        client = InferenceClient(q)
        t0 = now()
        (r,) = client.infer([[5, 6]], max_new=1, timeout=20.0)
        waited = now() - t0
        assert r.success and r.value == [7]
        # it waited out the deadline knob (partial flush), not a full
        # batch that would never come
        assert waited >= 0.8 * (spec.max_batch_delay_ms / 1000.0)
        assert loop.stats["decode_steps"] == 0
        assert eng.prefill_shapes == [(1, 8)]
    finally:
        _stop_local(q, spec, th)


def test_serve_loop_compaction_on_early_retire():
    """Mixed max_new in one bucket: short rows retire early and the
    engine state is gathered down to the survivor's batch bucket."""
    spec = ServeSpec(engine_factory=_stub_factory, max_batch=4,
                     prompt_buckets=(8,), max_batch_delay_ms=5.0)
    eng = _StubEngine()
    q, loop, th = _local_shard(spec, engine=eng)
    try:
        client = InferenceClient(q)
        tids = [q.send_inference([10], max_new=1),
                q.send_inference([20], max_new=1),
                q.send_inference([30], max_new=1),
                q.send_inference([40], max_new=6)]
        res = client.gather(tids, timeout=20.0)
        assert [r.value for r in res] == [[11], [21], [31],
                                          [41, 42, 43, 44, 45, 46]]
    finally:
        _stop_local(q, spec, th)
    # 4-row prefill, then a gather down to 1 survivor
    assert eng.prefill_shapes[0] == (4, 8)
    assert 1 in eng.gather_sizes
    assert loop.stats["compactions"] >= 1


def test_serve_loop_rejects_oversized_and_empty_prompts():
    spec = ServeSpec(engine_factory=_stub_factory, max_batch=4,
                     prompt_buckets=(8,), max_batch_delay_ms=5.0)
    q, loop, th = _local_shard(spec, engine=_StubEngine())
    try:
        client = InferenceClient(q)
        res = client.infer([[1] * 9, [2, 3]], max_new=2, timeout=20.0)
        assert not res[0].success and "outside buckets" in res[0].error
        assert res[1].success and res[1].value == [4, 5]
        assert q.active_count == 0
    finally:
        _stop_local(q, spec, th)
    assert loop.stats["errors"] == 1


def test_serve_loop_continuous_admission():
    """A second wave submitted while the first is mid-decode is admitted
    between decode steps, not after the first wave completes: total wall
    time is far below sequential group execution."""
    spec = ServeSpec(engine_factory=_stub_factory, max_batch=2,
                     prompt_buckets=(8,), max_batch_delay_ms=2.0)
    eng = _StubEngine(step_sleep=0.02)
    q, loop, th = _local_shard(spec, engine=eng)
    try:
        client = InferenceClient(q)
        first = client.submit([[1, 2], [3, 4]], max_new=20)
        time.sleep(0.1)                     # first group is mid-decode
        second = client.submit([[5, 6], [7, 8]], max_new=20)
        res = client.gather(first + second, timeout=30.0)
        assert all(r.success for r in res)
    finally:
        _stop_local(q, spec, th)
    # both groups were in flight concurrently: the loop interleaved
    # their steps (2 groups x 19 steps each, but admitted overlapping)
    assert loop.stats["prefills"] == 2
    assert loop.stats["decode_steps"] >= 38


# ---------------------------------------------------------------------------
# synapp steering: the proxy-model scorer routed through a shard
# ---------------------------------------------------------------------------

def test_synapp_scored_steering_local():
    """ML-in-the-loop synapp: every submission ranks candidates through
    the scorer shard (an in-thread serve loop on the local backend) and
    the campaign still completes exactly."""
    from repro.apps.synapp import SynConfig, run_synapp
    cfg = SynConfig(T=8, D=0.0, I=1 << 10, N=2, use_value_server=False,
                    score_candidates=3)
    res = run_synapp(cfg)
    assert res["completed_total"] == 8
    assert res["scored"] == 8 * 3


@pytest.mark.slow
def test_synapp_scored_steering_proc():
    """Same steering loop with the scorer as a forked shard process."""
    from repro.apps.synapp import SynConfig, run_synapp
    cfg = SynConfig(T=8, D=0.0, I=1 << 10, N=2, use_value_server=False,
                    backend="proc", score_candidates=3)
    res = run_synapp(cfg)
    assert res["completed_total"] == 8
    assert res["scored"] == 8 * 3


# ---------------------------------------------------------------------------
# chaos: SIGKILL a shard mid-batch (proc backend, forked shard)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_shard_sigkill_redelivers_exactly_once():
    """Kill -9 a shard while batches are in flight: its detached leases
    expire and every undelivered request redelivers to the replacement
    shard; rows the dead shard already streamed out are deduped by the
    result claim.  Zero lost, zero duplicated."""
    spec = ServeSpec(engine_factory=_slow_stub_factory, max_batch=4,
                     prompt_buckets=(8,), max_batch_delay_ms=5.0)
    q = ColmenaQueues([], backend="proc", lease_timeout=1.0,
                      serve_spec=spec)
    procs = []
    try:
        procs.append(start_inference_shard(
            q.transport.address, spec, lease_timeout=1.0,
            identity="infer@chaos:0"))
        client = InferenceClient(q)
        tids = client.submit([[i + 1, i + 2] for i in range(12)],
                             max_new=6)
        # wait for proof the shard is mid-campaign (some results out,
        # some requests still leased), then kill it without warning
        got: dict = {}
        deadline = time.time() + 30
        while not got and time.time() < deadline:
            for r in q.get_results(spec.topic, max_n=64, timeout=0.5):
                got.setdefault(r.task_id, []).append(r)
        assert got, "shard produced nothing before the kill"
        assert len(got) < 12, "campaign finished before the kill"
        os.kill(procs[0].pid, signal.SIGKILL)
        procs[0].join(timeout=5)
        # replacement shard: the expired leases' requests land on it
        procs.append(start_inference_shard(
            q.transport.address, spec, lease_timeout=1.0,
            identity="infer@chaos:1"))
        deadline = time.time() + 60
        while len(got) < 12 and time.time() < deadline:
            for r in q.get_results(spec.topic, max_n=64, timeout=0.5):
                got.setdefault(r.task_id, []).append(r)
        # zero lost ...
        assert sorted(got) == sorted(tids)
        # ... zero duplicated (the claim admits one publish per id) ...
        dupes = {t: len(rs) for t, rs in got.items() if len(rs) > 1}
        assert not dupes, dupes
        # ... and every value is the right echo chain regardless of
        # which incarnation served it
        for i, t in enumerate(tids):
            (r,) = got[t]
            assert r.success, r.error
            assert r.value == [i + 3 + k for k in range(6)]
        assert q.active_count == 0
        # the queue stays quiet: nothing redelivers after completion
        assert q.get_results(spec.topic, max_n=64, timeout=1.5) == []
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=3)
        q.shutdown()
