"""Multi-device distribution modes, validated in a subprocess with 8 forced
host devices (jax locks the device count at first init, so the main test
process cannot do this itself)."""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config, SHAPES, ShardingConfig, TrainConfig
from repro.distributed import axisenv, sharding as shd
from repro.models import api, moe
from repro.launch import steps

mesh = jax.make_mesh((2, 4), ('data', 'model'))

# 1. shard_map EP MoE == GSPMD dropping path (no drops)
cfg = get_config('kimi-k2-1t-a32b', reduced=True).replace(
    capacity_factor=8.0, compute_dtype='float32', param_dtype='float32')
params = api.init_params(cfg, jax.random.PRNGKey(1))
p = jax.tree.map(lambda t: t[0], params['stack']['uniform']['ffn'])
x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model))
y_ref, _ = moe.moe_dropping(p, x, cfg)
def f(p_, x_):
    with axisenv.activation_axes(batch=('data',), batch_sizes=(2,),
                                 model='model', model_size=4, mesh=mesh):
        return moe.moe_ep(p_, x_, cfg)
with mesh:
    y_ep, _ = jax.jit(f, in_shardings=(
        None, NamedSharding(mesh, P('data', None, None))))(p, x)
assert float(jnp.max(jnp.abs(y_ep - y_ref))) < 1e-4
print('EP_OK')

# 1b. fill-gather MoE dispatch under GSPMD with the seq-parallel layout
# (token axis sharded over "model") matches the unsharded reference --
# regression net for the concat-across-a-sharded-dim miscompile class
def g(p_, x_):
    with axisenv.activation_axes(batch=('data',), batch_sizes=(2,),
                                 model='model', model_size=4, mesh=mesh):
        return moe.moe_dropping(p_, x_, cfg)
with mesh:
    y_sp, _ = jax.jit(g, in_shardings=(
        None, NamedSharding(mesh, P('data', 'model', None))))(p, x)
assert float(jnp.max(jnp.abs(y_sp - y_ref))) < 1e-4
print('SP_MOE_OK')

# 2. a real sharded train step runs and matches the single-device step
cfg2 = get_config('internlm2-1.8b', reduced=True).replace(remat='none')
tc = TrainConfig(lr=1e-3, warmup_steps=0, total_steps=10)
for mode in ('dp_tp', 'dp_only', 'fsdp_tp'):
    sc = ShardingConfig(mode=mode)
    shape = SHAPES['train_4k']
    import dataclasses
    shape = dataclasses.replace(shape, seq_len=64, global_batch=8)
    with mesh:
        jfn, args = steps.build_program(cfg2, shape, mesh, tc=tc, sc=sc)
        state = steps.init_state(cfg2, jax.random.PRNGKey(0))
        batch = {
            'tokens': jnp.zeros((8, 64), jnp.int32),
            'labels': jnp.ones((8, 64), jnp.int32),
        }
        new_state, metrics = jfn(state, batch)
        loss = float(metrics['loss'])
        assert np.isfinite(loss), (mode, loss)
        print(f'{mode}_loss={loss:.6f}')
print('MODES_OK')
"""


@pytest.mark.slow
def test_multidevice_modes():
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                         capture_output=True, text=True, timeout=900)
    assert "EP_OK" in out.stdout, out.stdout + out.stderr
    assert "SP_MOE_OK" in out.stdout, out.stdout + out.stderr
    assert "MODES_OK" in out.stdout, out.stdout + out.stderr
    # every mode computes the same loss (sharding never changes semantics)
    losses = [float(line.split("=")[1]) for line in out.stdout.splitlines()
              if "_loss=" in line]
    assert len(losses) == 3
    # bf16 partial-sum order differs across shardings; semantics identical
    assert max(losses) - min(losses) < 0.02, losses
