"""Serving engine behaviour."""
import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import api
from repro.serving.engine import Engine


def test_generate_shapes_and_determinism():
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new=6)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(3, 16), dtype=np.int32)
    out1 = eng.generate(prompts)
    out2 = eng.generate(prompts)
    assert out1.shape == (3, 22)
    np.testing.assert_array_equal(out1, out2)   # greedy = deterministic
    np.testing.assert_array_equal(out1[:, :16], prompts)


def test_generate_matches_full_forward_argmax():
    """Greedy decode via the KV cache equals argmax over repeated full
    forward passes (incremental == recomputed)."""
    import jax.numpy as jnp
    cfg = get_config("qwen3-8b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new=4)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(2, 12), dtype=np.int32)
    out = eng.generate(prompts)

    toks = jnp.asarray(prompts)
    for _ in range(4):
        logits, _ = api.forward(params, cfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(out, np.asarray(toks))


def test_engine_ssm_arch():
    cfg = get_config("rwkv6-3b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new=4)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(2, 8), dtype=np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 12)
    # first call per shape is compile-dominated: it counts as warmup,
    # not steady-state throughput
    assert eng.throughput() == 0
    assert eng.stats["compile_wall"] > 0
    eng.generate(prompts)
    assert eng.throughput() > 0
    assert eng.stats["wall"] > 0


def test_engine_stepwise_matches_generate():
    """The shard's stepwise prefill/decode path emits exactly the tokens
    ``generate`` would, and ``gather_rows`` keeps the surviving rows'
    continuations identical after a slot-reuse compaction."""
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_new=6)
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab_size, size=(4, 16), dtype=np.int32)
    ref = eng.generate(prompts, max_new=6)

    first, state = eng.prefill_batch(prompts, reserve=16 + 6)
    got = [first]
    for _ in range(2):
        got.append(eng.decode_batch(state))
    # retire rows 1 and 3 mid-generation; survivors keep decoding
    state = eng.gather_rows(state, [0, 2])
    tail = [eng.decode_batch(state) for _ in range(3)]
    full = np.stack(got, axis=1)
    np.testing.assert_array_equal(full, ref[:, 16:16 + 3])
    np.testing.assert_array_equal(np.stack(tail, axis=1),
                                  ref[[0, 2], 16 + 3:])
