"""The lock-order witness: seeded inversions must fail, the fabric's
real acquisition graph must stay inside analysis/lock_order.toml."""
import json
import threading
from pathlib import Path

import pytest

from repro.analysis import witness as W
from repro.analysis.witness import (LockOrderError, Witness, WitnessLock,
                                    load_lock_order, read_sink)

REPO = Path(__file__).resolve().parent.parent
LOCK_ORDER = REPO / "analysis" / "lock_order.toml"


def run_in_thread(fn):
    box = {}

    def wrapper():
        try:
            box["result"] = fn()
        except BaseException as e:          # noqa: BLE001 - re-raised below
            box["error"] = e

    t = threading.Thread(target=wrapper)
    t.start()
    t.join(timeout=10)
    assert not t.is_alive(), "witness thread hung"
    return box


# ---------------------------------------------------------------------------
# seeded AB/BA inversion: the satellite-mandated witness self-test
# ---------------------------------------------------------------------------


def test_seeded_ab_ba_inversion_fails_the_witness():
    w = Witness()
    a = WitnessLock(w, "fixture:A")
    b = WitnessLock(w, "fixture:B")
    with a:
        with b:                             # records A -> B
            pass

    def inverted():
        with b:
            with a:                         # would close B -> A -> B
                pass

    box = run_in_thread(inverted)
    assert isinstance(box.get("error"), LockOrderError)
    msg = str(box["error"])
    assert "fixture:A" in msg and "fixture:B" in msg

    # the witness fails on the *attempt*, before any deadlock: both locks
    # must be free again
    assert not a.locked() and not b.locked()


def test_inversion_detected_without_interleaving():
    # no concurrency at all: the graph alone carries the order
    w = Witness()
    a, b = WitnessLock(w, "X"), WitnessLock(w, "Y")
    with a, b:
        pass
    with pytest.raises(LockOrderError):
        with b, a:
            pass


def test_longer_cycle_detected():
    w = Witness()
    a, b, c = (WitnessLock(w, n) for n in "ABC")
    with a, b:
        pass
    with b, c:
        pass
    with pytest.raises(LockOrderError):     # C -> A closes A->B->C->A
        with c, a:
            pass


# ---------------------------------------------------------------------------
# wrapper semantics
# ---------------------------------------------------------------------------


def test_reentrant_rlock_records_no_self_edge():
    w = Witness()
    r = WitnessLock(w, "R", threading.RLock())
    with r, r:
        pass
    assert w.edges == {} and w.self_edges == {}


def test_same_site_two_instances_raises_unless_declared():
    w = Witness()
    c1 = WitnessLock(w, "site:cond")
    c2 = WitnessLock(w, "site:cond")
    with pytest.raises(LockOrderError, match="self_edges"):
        with c1, c2:
            pass

    w2 = Witness(allowed_self_edges={"site:cond"})
    c1 = WitnessLock(w2, "site:cond")
    c2 = WitnessLock(w2, "site:cond")
    with c1, c2:
        pass
    assert "site:cond" in w2.self_edges


def test_condition_over_witness_lock_wait_notify():
    # a real threading.Condition built on a WitnessLock must wait/notify
    # correctly (the witness supplies the private Condition protocol)
    w = Witness()
    lk = WitnessLock(w, "L")
    cond = threading.Condition(lk)
    state = []

    def waiter():
        with cond:
            while not state:
                cond.wait(5)
            return state[0]

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        state.append("done")
        cond.notify_all()
    t.join(timeout=10)
    assert not t.is_alive()


def test_nonblocking_acquire_failure_records_nothing():
    w = Witness()
    a, b = WitnessLock(w, "A"), WitnessLock(w, "B")
    with a:
        got = run_in_thread(lambda: b.acquire(False) and (b.release(),))
        assert "error" not in got
    # only the other thread touched b, with nothing held: no edges
    assert ("A", "B") not in w.edges or w.edges == {}


# ---------------------------------------------------------------------------
# sink + known-order file
# ---------------------------------------------------------------------------


def test_edges_stream_to_sink_eagerly(tmp_path):
    sink = tmp_path / "edges.jsonl"
    w = Witness(sink=str(sink))
    a, b = WitnessLock(w, "A"), WitnessLock(w, "B")
    with a, b:
        # written while still held: an os._exit here would lose nothing
        assert sink.exists() and "edge" in sink.read_text()
    edges, selfs = read_sink(sink)
    assert ("A", "B") in edges and selfs == {}


def test_read_sink_merges_duplicate_lines(tmp_path):
    sink = tmp_path / "edges.jsonl"
    rec = json.dumps({"edge": ["A", "B"], "site": "x.py:1"})
    sink.write_text(rec + "\n" + rec + "\n")
    edges, _ = read_sink(sink)
    assert edges == {("A", "B"): "x.py:1"}


def test_checked_in_lock_order_parses():
    edges, selfs = load_lock_order(LOCK_ORDER)
    # the documented claim -> cond coupling must stay on record
    assert ("core/transport/broker.py:self._claim_lock",
            "core/transport/broker.py:self.cond") in edges
    assert "core/transport/broker.py:self.cond" in selfs


def test_fallback_toml_parser_matches_format():
    # Python 3.10 has no tomllib; the subset parser must read the real file
    text = LOCK_ORDER.read_text()
    arrays = W._parse_string_arrays(text)
    assert arrays["edges.pairs"], "no edges parsed"
    assert all(" -> " in p for p in arrays["edges.pairs"])
    assert arrays["self_edges.allowed"]


# ---------------------------------------------------------------------------
# the real fabric under an installed witness
# ---------------------------------------------------------------------------


def test_local_fabric_edges_stay_inside_lock_order(tmp_path):
    if W.installed() is not None:
        pytest.skip("witness already installed session-wide")
    known_edges, allowed_self = load_lock_order(LOCK_ORDER)
    w = W.install(Witness(allowed_self_edges=allowed_self))
    try:
        # locks are instantiated per-object, so instances created now are
        # witnessed even though the modules were imported long ago
        from repro.core.queues import ColmenaQueues
        from repro.core.transport.base import Envelope
        from repro.core.transport.local import LocalTransport

        t = LocalTransport()
        ch = t.channel("t", "requests")
        assert ch.put(Envelope(0.0, b"x", {}), claim="task-0")
        assert not ch.put(Envelope(0.0, b"x", {}), claim="task-0")
        assert len(ch.get_batch(4, timeout=0.5)) == 1
        t.snapshot()                        # multi-cond consistent cut

        q = ColmenaQueues(["t"])            # queues._lock/_all_done
        q.send_task(3, method="noop", topic="t")
        assert q.get_task("t", timeout=1) is not None
        assert not q.wait_until_done(timeout=0.05)
    finally:
        W.uninstall()
    assert set(w.edges) <= known_edges, (
        f"undeclared edges: {set(w.edges) - known_edges}")
    assert set(w.self_edges) <= allowed_self
