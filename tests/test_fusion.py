"""Numerics-agreement regressions for the stacked-axis fusion family.

``fuse_ffn`` once miscompiled under GSPMD because the gate/up halves were
concatenated (then split) across the TP-sharded ff dim; the fix fuses
along a *new leading axis* so shard boundaries never move.  These tests
pin the same contract for every fused path the audit touched: fused and
unfused implementations must agree bit-tightly on the same inputs, and
the MoE dispatch/combine gathers (now fill-mode instead of pad-row
concats along sharded dims) must keep matching the one-hot einsum oracle
even when capacity drops exercise the out-of-bounds fill path.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import api, moe
from repro.models.attention import attention_params, project_qkv
from repro.models.mlp import mlp, mlp_params


class _ParamMaker:
    """Deterministic dense param factory matching the mk.param call shape."""

    def __init__(self, seed=0):
        self.key = jax.random.PRNGKey(seed)

    def param(self, shape, axes, fan_in=None, init=None):
        self.key, sub = jax.random.split(self.key)
        if init == "ones":
            return jnp.ones(shape, jnp.float32)
        scale = 1.0 / np.sqrt(fan_in or shape[-1])
        return jax.random.normal(sub, shape, jnp.float32) * scale


def _gqa_cfg(**kw):
    cfg = get_config("qwen3-8b", reduced=True)
    return cfg.replace(compute_dtype="float32", param_dtype="float32", **kw)


def test_fused_kv_matches_unfused():
    cfg = _gqa_cfg(fuse_kv=True)
    assert cfg.num_kv_heads < cfg.num_heads      # exercise the GQA shapes
    params = attention_params(_ParamMaker(), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model),
                          jnp.float32)
    qf, kf, vf = project_qkv(params, x, cfg.replace(fuse_kv=True))
    qu, ku, vu = project_qkv(params, x, cfg.replace(fuse_kv=False))
    np.testing.assert_allclose(np.asarray(qf), np.asarray(qu))
    np.testing.assert_allclose(np.asarray(kf), np.asarray(ku),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(vf), np.asarray(vu),
                               rtol=1e-6, atol=1e-6)


def test_fused_ffn_matches_unfused():
    cfg = _gqa_cfg()
    params = mlp_params(_ParamMaker(), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model),
                          jnp.float32)
    yf = mlp(params, x, cfg.replace(fuse_ffn=True))
    yu = mlp(params, x, cfg.replace(fuse_ffn=False))
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yu),
                               rtol=1e-6, atol=1e-6)


def _moe_setup(cf):
    cfg = get_config("kimi-k2-1t-a32b", reduced=True).replace(
        capacity_factor=cf, compute_dtype="float32", param_dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    p = jax.tree.map(lambda t: t[0], params["stack"]["uniform"]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_moe_fill_gather_matches_oracle_under_drops():
    """Tight capacity forces both the empty-slot fill (dispatch) and the
    dropped-assignment OOB fill (combine); the einsum oracle computes the
    same semantics with explicit one-hot masks."""
    cfg, p, x = _moe_setup(cf=0.5)
    y1, a1 = moe.moe_dropping(p, x, cfg)
    y2, a2 = moe.moe_einsum(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y1)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(a1) - float(a2)) < 1e-6
