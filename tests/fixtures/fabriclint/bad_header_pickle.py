"""Fixture: a pickled blob smuggled inside a wire header -- headers are
small plain dicts pickled once per hop; payload bytes ride the frame
body (single-pickle-per-hop).
Must trip the frame-header-hygiene pass."""
import pickle


def send_result(client, topic, result):
    header, _ = client.request(
        {"op": "result", "topic": topic,
         "value": pickle.dumps(result)})     # blob belongs in the body
    return header
