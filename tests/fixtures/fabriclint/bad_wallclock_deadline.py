"""Fixture: lease deadline arithmetic on time.time() -- an NTP step or
DST change silently expires (or immortalizes) every lease in flight.
Must trip the monotonic-deadlines pass."""
import time


def lease_expired(granted_at: float, lease_timeout: float) -> bool:
    return time.time() - granted_at > lease_timeout
