"""Fixture: a consumer unlinking the shared-memory segment it just
read -- segment ownership transferred to the broker with the frame, and
an expired lease redelivers the descriptor to the NEXT consumer; this
unlink destroys that redelivered copy's payload.
Must trip the shm-segment-lifecycle pass."""
from repro.core.transport import shm


def consume(desc):
    try:
        data = shm.read_segment(desc)
    except OSError:
        return None
    shm.unlink_segment(desc)            # consumers only map and read
    return data
