"""Fixture: Condition.wait() with no while-predicate loop and no timeout
-- a spurious wakeup or stolen notify strands the waiter forever.
Must trip the wait-needs-predicate pass."""
import threading


class LostWakeup:
    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self.ready = False

    def consume(self):
        with self._cond:
            if not self.ready:          # an `if`, not a `while`: broken
                self._cond.wait()
            self.ready = False
