"""Fixture: daemon thread with no stop method, no sentinel, no join --
it spins until the interpreter dies, holding whatever it captured.
Must trip the thread-lifecycle pass."""
import threading
import time


class Poller:
    def __init__(self, fn):
        self.fn = fn
        threading.Thread(target=self._loop, daemon=True).start()

    def _loop(self):
        while True:                     # no flag, no sentinel, no join
            self.fn()
            time.sleep(1.0)
