"""Fixture: lazy init under `if self._x is None` with no lock -- two
racing callers each build the resource and one copy leaks (the PR-5
split-replication-FIFO bug class).
Must trip the guarded-lazy-init pass."""
import queue
import threading


class SplitQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self._q = None

    def submit(self, item):
        if self._q is None:             # unguarded: racing callers split it
            self._q = queue.SimpleQueue()
        self._q.put(item)
