"""Fixture: retry=True on an op that is NOT in the IDEMPOTENT_OPS
registry ('put' may already have been applied before the connection
died; a resend double-applies it).
Must trip the idempotent-retry-registry pass."""


def resubmit(client, topic, blob):
    header, _ = client.request(
        {"op": "put", "topic": topic, "kind": "task"}, blob,
        retry=True)
    return header
