"""Fixture: a span whose name is not declared in
``repro/observability/names.py`` -- the report merges sinks and maps
the Fig.-5 decomposition by name, so an undeclared name doesn't error,
it just fragments the timeline into a series nobody aggregates.
Must trip the span-name-registry pass."""
from repro import observability as obs


def execute(task_id, fn):
    with obs.span(task_id, "task_execuet"):     # typo'd, undeclared
        return fn()
