"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.mamba2_ssd import ref as ssd_ref
from repro.kernels.mamba2_ssd.mamba2_ssd import ssd_pallas
from repro.kernels.moe_gmm.moe_gmm import gmm
from repro.kernels.moe_gmm.ref import gmm_reference
from repro.kernels.mpnn_mp.mpnn_mp import message_pass_pallas
from repro.kernels.mpnn_mp.ref import message_pass_reference
from repro.kernels.rwkv6_scan import ref as wkv_ref
from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6_pallas

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,KVH,hd,causal,window,softcap,off",
    [
        (2, 128, 128, 4, 2, 32, True, None, None, 0),
        (1, 256, 256, 4, 4, 64, True, 64, None, 0),
        (2, 128, 128, 8, 2, 32, True, None, 50.0, 0),
        (1, 128, 256, 4, 2, 32, True, None, None, 128),
        (2, 128, 128, 4, 1, 32, False, None, None, 0),
        (1, 64, 64, 2, 2, 128, True, 32, 30.0, 0),
    ])
def test_flash_attention(B, Sq, Sk, H, KVH, hd, causal, window, softcap,
                         off, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KVH, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KVH, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, q_offset=off,
                          block_q=64, block_k=64)
    ref = attention_reference(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_offset=off)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,H,P,G,N,Q", [
    (2, 256, 4, 32, 1, 16, 64),
    (1, 128, 8, 64, 2, 32, 128),
    (2, 256, 4, 32, 4, 16, 64),
])
def test_mamba2_ssd_kernel(B, L, H, P, G, N, Q, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    la = (-jnp.abs(jax.random.normal(ks[1], (B, L, H))) * 0.3)
    b = jax.random.normal(ks[2], (B, L, G, N), dtype)
    c = jax.random.normal(ks[3], (B, L, G, N), dtype)
    s0 = jax.random.normal(ks[4], (B, H, P, N))
    y1, s1 = ssd_pallas(x, la, b, c, s0, chunk=Q)
    y2, s2 = ssd_ref.ssd_naive(x, la, b, c, s0)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=tol, atol=tol)


def test_mamba2_chunked_ref_matches_naive():
    ks = jax.random.split(KEY, 5)
    B, L, H, P, G, N = 2, 256, 4, 16, 2, 8
    x = jax.random.normal(ks[0], (B, L, H, P))
    la = -jnp.abs(jax.random.normal(ks[1], (B, L, H)))  # strong decay
    b = jax.random.normal(ks[2], (B, L, G, N))
    c = jax.random.normal(ks[3], (B, L, G, N))
    s0 = jax.random.normal(ks[4], (B, H, P, N))
    y1, s1 = ssd_ref.ssd_chunked(x, la, b, c, s0, chunk=32)
    y2, s2 = ssd_ref.ssd_naive(x, la, b, c, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,L,H,K,V,Q", [
    (2, 128, 4, 32, 32, 64),
    (1, 128, 2, 64, 64, 32),
])
def test_rwkv6_kernel(B, L, H, K, V, Q):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, L, H, K))
    k = jax.random.normal(ks[1], (B, L, H, K))
    v = jax.random.normal(ks[2], (B, L, H, V))
    lw = -jnp.abs(jax.random.normal(ks[3], (B, L, H, K))) * 2.0
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, K, V))
    y1, s1 = wkv6_pallas(r, k, v, lw, u, s0, chunk=Q)
    y2, s2 = wkv_ref.wkv6_naive(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_chunked_ref_strong_decay_stable():
    """The hybrid chunked form must survive decay regimes where the naive
    parallel form overflows (|log w| large)."""
    ks = jax.random.split(KEY, 5)
    B, L, H, K, V = 1, 256, 2, 16, 16
    r = jax.random.normal(ks[0], (B, L, H, K))
    k = jax.random.normal(ks[1], (B, L, H, K))
    v = jax.random.normal(ks[2], (B, L, H, V))
    lw = -jnp.abs(jax.random.normal(ks[3], (B, L, H, K))) * 11.9
    u = jax.random.normal(ks[4], (H, K))
    y1, s1 = wkv_ref.wkv6_chunked(r, k, v, lw, u, chunk=64)
    y2, s2 = wkv_ref.wkv6_naive(r, k, v, lw, u)
    assert np.all(np.isfinite(np.asarray(y1)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(4, 128, 256, 512), (8, 64, 128, 128)])
def test_gmm_kernel(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 2)
    xe = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    o1 = gmm(xe, w, block_c=64, block_f=128, block_d=128)
    o2 = gmm_reference(xe, w)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,N,Hd", [(3, 16, 32), (2, 8, 64)])
def test_mpnn_kernel(B, N, Hd):
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (B, N, Hd))
    e = jax.random.normal(ks[1], (B, N, N, Hd, Hd)) * 0.1
    adj = (jax.random.uniform(ks[2], (B, N, N)) > 0.5).astype(jnp.float32)
    m1 = message_pass_pallas(h, e, adj)
    m2 = message_pass_reference(h, e, adj)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               rtol=1e-4, atol=1e-4)
